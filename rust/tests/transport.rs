//! Integration tests for the socket transport: concurrent multi-client
//! sessions, streaming event order, malformed-frame isolation, and
//! graceful shutdown/drain.

use dare::service::transport::{spawn, Listener, Server, SessionOpts, Stream};
use dare::service::{Json, Service, ServiceConfig};
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A server on a fresh unix socket in the temp dir, plus the handles the
/// tests need to drive and drain it.
struct Harness {
    path: PathBuf,
    server: Server,
    shutdown: Arc<AtomicBool>,
    service: Arc<Service>,
}

impl Harness {
    fn start(tag: &str) -> Harness {
        Harness::start_with(tag, SessionOpts::default())
    }

    fn start_with(tag: &str, opts: SessionOpts) -> Harness {
        let path = std::env::temp_dir()
            .join(format!("dare-transport-{tag}-{}.sock", std::process::id()));
        let listener = Listener::bind_unix(path.to_str().unwrap()).expect("bind unix socket");
        let service = Arc::new(Service::start(ServiceConfig::with_workers(2)));
        let shutdown = Arc::new(AtomicBool::new(false));
        let server = spawn(listener, service.clone(), opts, shutdown.clone());
        Harness { path, server, shutdown, service }
    }

    fn connect(&self) -> Stream {
        Stream::connect_unix(self.path.to_str().unwrap()).expect("connect")
    }

    /// Flag-initiated drain; must terminate promptly.
    fn stop(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.server.join();
        let _ = std::fs::remove_file(&self.path);
    }
}

fn job_line(id: &str, variant: &str) -> String {
    format!(
        "{{\"id\":\"{id}\",\"kernel\":\"sddmm\",\"dataset\":\"pubmed\",\
         \"variant\":\"{variant}\",\"scale\":0.04}}"
    )
}

/// Open the (mandatory) v2 handshake on a fresh connection.
fn send_hello(stream: &mut Stream) {
    writeln!(stream, "{{\"cmd\":\"hello\",\"proto\":2}}").unwrap();
}

/// Read events until (and including) the first `done`; panics on a
/// non-event line or a closed connection. The server's `hello` answer
/// is tolerated anywhere before `done`.
fn read_until_done(reader: &mut impl BufRead) -> (Vec<Json>, Json) {
    let mut results = Vec::new();
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("read event line");
        assert!(n > 0, "connection closed before done event");
        let v = Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
        match v.get("event").and_then(Json::as_str) {
            Some("result") => results.push(v),
            Some("hello") => {}
            Some("done") => {
                let metrics = v.get("metrics").expect("done carries metrics").clone();
                return (results, metrics);
            }
            other => panic!("unexpected event {other:?} in {line:?}"),
        }
    }
}

const VARIANTS: [&str; 4] = ["baseline", "nvr", "dare-fre", "dare-full"];

#[test]
fn two_clients_pipeline_jobs_and_correlate_by_id() {
    let h = Harness::start("multi");
    let clients: Vec<_> = ["a", "b"]
        .into_iter()
        .map(|tag| {
            let path = h.path.clone();
            std::thread::spawn(move || {
                let mut stream = Stream::connect_unix(path.to_str().unwrap()).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                send_hello(&mut stream);
                // Pipelined: all four jobs go out before any read.
                for (i, variant) in VARIANTS.iter().enumerate() {
                    writeln!(stream, "{}", job_line(&format!("{tag}/{i}"), variant)).unwrap();
                }
                writeln!(stream, "{{\"cmd\":\"done\"}}").unwrap();
                stream.flush().unwrap();
                read_until_done(&mut reader)
            })
        })
        .collect();
    let outputs: Vec<_> = clients.into_iter().map(|c| c.join().expect("client")).collect();
    for (tag, (results, metrics)) in ["a", "b"].iter().zip(&outputs) {
        assert_eq!(results.len(), 4, "client {tag}");
        // Responses stream in completion order — correlate by id: each
        // client sees exactly its own ids, each exactly once.
        let mut ids: Vec<String> = results
            .iter()
            .map(|v| v.get("id").and_then(Json::as_str).expect("id echoed").to_string())
            .collect();
        ids.sort();
        let want: Vec<String> = (0..4).map(|i| format!("{tag}/{i}")).collect();
        assert_eq!(ids, want);
        for v in results {
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "client {tag}");
            assert!(v.get("cycles").and_then(Json::as_u64).unwrap() > 0);
        }
        assert_eq!(metrics.get("jobs").and_then(Json::as_u64), Some(4));
        assert_eq!(metrics.get("failed").and_then(Json::as_u64), Some(0));
    }
    // Both clients drew on ONE service: 8 jobs total, and the identical
    // sddmm/pubmed workloads were shared across connections.
    let m = h.service.metrics();
    assert_eq!(m.jobs_completed, 8);
    assert!(m.cache.hit_rate() > 0.0, "cross-client reuse: {}", m.cache.summary());
    h.stop();
}

#[test]
fn streaming_results_precede_done_and_counts_match() {
    let h = Harness::start("stream");
    let mut stream = h.connect();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    send_hello(&mut stream);
    let n = 6;
    for i in 0..n {
        writeln!(stream, "{}", job_line(&format!("s/{i}"), VARIANTS[i % VARIANTS.len()]))
            .unwrap();
    }
    writeln!(stream, "{{\"cmd\":\"done\"}}").unwrap();
    stream.flush().unwrap();
    // read_until_done asserts the ordering property itself: it panics on
    // any non-result event before done, so reaching here means every
    // result preceded the done summary.
    let (results, metrics) = read_until_done(&mut reader);
    assert_eq!(results.len(), n);
    assert_eq!(metrics.get("jobs").and_then(Json::as_u64), Some(n as u64));
    // The done summary carries the whole-service snapshot too.
    let service = metrics.get("service").expect("service snapshot");
    assert_eq!(service.get("jobs_completed").and_then(Json::as_u64), Some(n as u64));
    h.stop();
}

#[test]
fn malformed_frame_is_isolated_to_its_connection() {
    let h = Harness::start("malformed");

    // Client A: garbage frame + a valid job. The garbage is answered
    // with a typed {"event":"error","code":"malformed",…} frame; the
    // valid job still runs.
    let mut a = h.connect();
    let mut a_reader = BufReader::new(a.try_clone().unwrap());
    send_hello(&mut a);
    writeln!(a, "this is not json at all").unwrap();
    writeln!(a, "{}", job_line("a/ok", "baseline")).unwrap();
    writeln!(a, "{{\"cmd\":\"done\"}}").unwrap();
    a.flush().unwrap();
    let mut a_results = Vec::new();
    let mut a_errors = Vec::new();
    let a_metrics = loop {
        let mut line = String::new();
        let n = a_reader.read_line(&mut line).expect("read event line");
        assert!(n > 0, "connection closed before done event");
        let v = Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
        match v.get("event").and_then(Json::as_str) {
            Some("result") => a_results.push(v),
            Some("error") => a_errors.push(v),
            Some("hello") => {}
            Some("done") => break v.get("metrics").expect("done carries metrics").clone(),
            other => panic!("unexpected event {other:?} in {line:?}"),
        }
    };
    assert_eq!(a_results.len(), 1);
    assert_eq!(a_errors.len(), 1);
    let bad = &a_errors[0];
    assert_eq!(bad.get("code").and_then(Json::as_str), Some("malformed"));
    assert!(bad.get("detail").and_then(Json::as_str).is_some());
    // Frame 1 is the hello; the garbage is frame 2.
    assert_eq!(bad.get("seq").and_then(Json::as_u64), Some(2), "points at frame 2");
    let good = &a_results[0];
    assert_eq!(good.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(good.get("id").and_then(Json::as_str), Some("a/ok"));
    assert_eq!(a_metrics.get("jobs").and_then(Json::as_u64), Some(2));
    assert_eq!(a_metrics.get("failed").and_then(Json::as_u64), Some(1));

    // The server survived: a second client connects and runs cleanly.
    let mut b = h.connect();
    let mut b_reader = BufReader::new(b.try_clone().unwrap());
    send_hello(&mut b);
    writeln!(b, "{}", job_line("b/0", "nvr")).unwrap();
    writeln!(b, "{{\"cmd\":\"done\"}}").unwrap();
    b.flush().unwrap();
    let (b_results, b_metrics) = read_until_done(&mut b_reader);
    assert_eq!(b_results.len(), 1);
    assert_eq!(b_results[0].get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(b_metrics.get("failed").and_then(Json::as_u64), Some(0));
    h.stop();
}

#[test]
fn metrics_cmd_over_socket_returns_live_snapshot() {
    let h = Harness::start("metrics");
    let mut stream = h.connect();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    send_hello(&mut stream);
    writeln!(stream, "{}", job_line("m/0", "baseline")).unwrap();
    writeln!(stream, "{{\"cmd\":\"metrics\"}}").unwrap();
    writeln!(stream, "{{\"cmd\":\"done\"}}").unwrap();
    stream.flush().unwrap();
    let (mut results, mut saw_metrics) = (0, false);
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("read line");
        assert!(n > 0, "connection closed before done event");
        let v = Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
        match v.get("event").and_then(Json::as_str) {
            Some("result") => results += 1,
            Some("hello") => {}
            Some("metrics") => {
                saw_metrics = true;
                let svc = v.get("service").expect("metrics carries a live snapshot");
                assert!(svc.get("jobs_submitted").and_then(Json::as_u64).unwrap() >= 1);
                let cache = svc.get("cache").expect("cache counters");
                assert!(cache.get("disk_hits").and_then(Json::as_u64).is_some());
                assert!(cache.get("bytes_on_disk").and_then(Json::as_u64).is_some());
            }
            Some("done") => break,
            other => panic!("unexpected event {other:?} in {line:?}"),
        }
    }
    assert_eq!(results, 1);
    assert!(saw_metrics, "a socket session must answer {{\"cmd\":\"metrics\"}}");
    h.stop();
}

#[test]
fn hello_handshake_over_socket_negotiates_v2() {
    let h = Harness::start("hello");
    let mut stream = h.connect();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "{{\"cmd\":\"hello\",\"proto\":2}}").unwrap();
    writeln!(stream, "{}", job_line("h/0", "baseline")).unwrap();
    writeln!(stream, "{{\"cmd\":\"done\"}}").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).expect("read hello reply");
    let v = Json::parse(line.trim()).unwrap();
    assert_eq!(v.get("event").and_then(Json::as_str), Some("hello"), "{line:?}");
    assert_eq!(v.get("proto").and_then(Json::as_u64), Some(2));
    let (results, metrics) = read_until_done(&mut reader);
    assert_eq!(results.len(), 1);
    assert_eq!(metrics.get("jobs").and_then(Json::as_u64), Some(1), "hello is not a job");
    h.stop();
}

#[test]
fn auth_socket_rejects_unauthenticated_and_serves_authed() {
    let h = Harness::start_with(
        "auth",
        SessionOpts { auth: Some("sesame".into()), ..SessionOpts::default() },
    );

    // No hello at all (a v1 client): one unauthorized error frame, then
    // the server closes the session without reading the job.
    let mut bad = h.connect();
    let mut bad_reader = BufReader::new(bad.try_clone().unwrap());
    writeln!(bad, "{}", job_line("bad/0", "baseline")).unwrap();
    bad.flush().unwrap();
    bad.shutdown_write();
    let mut lines = Vec::new();
    loop {
        let mut line = String::new();
        if bad_reader.read_line(&mut line).expect("read rejection") == 0 {
            break;
        }
        lines.push(line.trim().to_string());
    }
    assert_eq!(lines.len(), 1, "error then close, no done: {lines:?}");
    let v = Json::parse(&lines[0]).unwrap();
    assert_eq!(v.get("event").and_then(Json::as_str), Some("error"));
    assert_eq!(v.get("code").and_then(Json::as_str), Some("unauthorized"));

    // Correct secret: handshake acknowledged, jobs served.
    let mut good = h.connect();
    let mut good_reader = BufReader::new(good.try_clone().unwrap());
    writeln!(good, "{{\"cmd\":\"hello\",\"proto\":2,\"auth\":\"sesame\"}}").unwrap();
    writeln!(good, "{}", job_line("good/0", "baseline")).unwrap();
    writeln!(good, "{{\"cmd\":\"done\"}}").unwrap();
    good.flush().unwrap();
    let mut line = String::new();
    good_reader.read_line(&mut line).expect("read hello reply");
    let v = Json::parse(line.trim()).unwrap();
    assert_eq!(v.get("event").and_then(Json::as_str), Some("hello"), "{line:?}");
    let (results, metrics) = read_until_done(&mut good_reader);
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(metrics.get("failed").and_then(Json::as_u64), Some(0));
    h.stop();
}

#[test]
fn no_hello_first_frame_is_rejected_even_without_auth() {
    // The v1 no-hello compatibility window is closed: the first frame
    // of every session must be a hello, auth or not.
    let h = Harness::start("nohello");
    let mut stream = h.connect();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "{}", job_line("v1/0", "baseline")).unwrap();
    stream.flush().unwrap();
    stream.shutdown_write();
    let mut lines = Vec::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line).expect("read rejection") == 0 {
            break;
        }
        lines.push(line.trim().to_string());
    }
    assert_eq!(lines.len(), 1, "error then close, no done: {lines:?}");
    let v = Json::parse(&lines[0]).unwrap();
    assert_eq!(v.get("event").and_then(Json::as_str), Some("error"));
    assert_eq!(v.get("code").and_then(Json::as_str), Some("malformed"));
    assert!(v.get("detail").and_then(Json::as_str).unwrap().contains("hello"));
    h.stop();
}

#[test]
fn bind_unix_refuses_to_replace_non_socket_files() {
    let path = std::env::temp_dir().join(format!("dare-notsocket-{}.txt", std::process::id()));
    std::fs::write(&path, "precious").unwrap();
    let err = Listener::bind_unix(path.to_str().unwrap()).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::AlreadyExists);
    assert_eq!(std::fs::read_to_string(&path).unwrap(), "precious", "file untouched");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn shutdown_cmd_drains_server_and_join_returns() {
    let h = Harness::start("shutdown");
    let mut stream = h.connect();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    send_hello(&mut stream);
    writeln!(stream, "{}", job_line("final", "dare-full")).unwrap();
    writeln!(stream, "{{\"cmd\":\"shutdown\"}}").unwrap();
    stream.flush().unwrap();
    // The in-flight job completes and the summary still arrives before
    // the server exits (graceful drain, not a dropped connection).
    let (results, metrics) = read_until_done(&mut reader);
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].get("id").and_then(Json::as_str), Some("final"));
    assert_eq!(metrics.get("jobs").and_then(Json::as_u64), Some(1));
    // join() must return on its own — no flag poke from the test.
    h.server.join();
    assert!(h.shutdown.load(Ordering::SeqCst), "session propagated the shutdown");
    let _ = std::fs::remove_file(&h.path);
}
