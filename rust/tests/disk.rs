//! Integration tests for the on-disk workload tier (`service::disk`):
//! warm-restart reuse through a whole `Service`, corrupt-entry
//! recovery, cross-"process" build coordination via the per-key file
//! lock, and the size-bounded GC.

use dare::coordinator::{BenchPoint, RunSpec};
use dare::kernels::{KernelKind, WorkloadKey};
use dare::service::disk::CODEC_VERSION;
use dare::service::{DiskConfig, DiskStore, Fetch, Service, ServiceConfig, WorkloadCache};
use dare::sim::Variant;
use dare::sparse::DatasetKind;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dare-e2e-disk-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn key(block: usize) -> WorkloadKey {
    WorkloadKey::new(KernelKind::Sddmm, DatasetKind::PubMed, block, false, 0.04)
}

fn store_at(dir: &Path) -> Arc<DiskStore> {
    Arc::new(DiskStore::open(DiskConfig::new(dir)).unwrap())
}

fn entry_files(dir: &Path) -> Vec<PathBuf> {
    let mut v: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|s| s.to_str()) == Some("dwl"))
        .collect();
    v.sort();
    v
}

/// The acceptance-criteria path end-to-end: a second *service* (≈ a
/// second `dare` process / a restarted `dare serve`) over the same
/// cache directory serves every unique workload from disk.
#[test]
fn warm_service_restart_hits_disk_for_every_unique_workload() {
    let dir = tmp_dir("warm-restart");
    let specs: Vec<RunSpec> = [Variant::Baseline, Variant::Nvr, Variant::DareFre]
        .into_iter()
        .flat_map(|v| {
            [DatasetKind::PubMed, DatasetKind::Gpt2Attention]
                .into_iter()
                .map(move |d| RunSpec::new(BenchPoint::new(KernelKind::Sddmm, d, 1, 0.04), v))
        })
        .collect();

    let cold_cfg = ServiceConfig {
        workers: 2,
        disk: Some(DiskConfig::new(&dir)),
        ..ServiceConfig::default()
    };
    let cold = Service::start(cold_cfg.clone());
    let cold_results = cold.run_batch(&specs);
    let c = cold.metrics().cache;
    assert_eq!(c.disk_hits, 0, "first run has nothing to reuse");
    assert_eq!(c.disk_misses, 2, "one probe per unique workload");
    assert!(c.bytes_on_disk > 0);
    drop(cold);

    // "Restart": a brand-new service, empty memory cache, same dir.
    let warm = Service::start(cold_cfg);
    let warm_results = warm.run_batch(&specs);
    let c = warm.metrics().cache;
    assert_eq!(c.disk_hits, 2, "every unique workload loads from disk");
    assert_eq!(c.disk_misses, 0);
    assert_eq!(c.builds(), 0, "the warm run compiles nothing");
    assert!(
        c.disk_hit_rate() >= 0.9,
        "warm-restart disk hit rate {} below the CI bar",
        c.disk_hit_rate()
    );
    // Disk-served builds are exact: identical simulation results.
    for (a, b) in cold_results.iter().zip(&warm_results) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.stats.cycles, b.stats.cycles, "{}", a.name);
        assert_eq!(a.stats.instrs_retired, b.stats.instrs_retired, "{}", a.name);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_corruption_class_rebuilds_instead_of_panicking() {
    let dir = tmp_dir("corruption");
    let k = key(1);
    store_at(&dir).store(&k, &k.build()).unwrap();
    let pristine = std::fs::read(&entry_files(&dir)[0]).unwrap();

    // (tag, mutate) pairs covering: truncated body, flipped body byte
    // (checksum), foreign codec version, garbage header.
    type Mutate = fn(&[u8]) -> Vec<u8>;
    let cases: [(&str, Mutate); 4] = [
        ("truncated", |b| b[..b.len() - 9].to_vec()),
        ("bit-flip", |b| {
            let mut v = b.to_vec();
            let mid = 24 + (v.len() - 24) / 2;
            v[mid] ^= 0x40;
            v
        }),
        ("future-version", |b| {
            let mut v = b.to_vec();
            let bumped = (CODEC_VERSION + 1).to_le_bytes();
            v[4] = bumped[0];
            v[5] = bumped[1];
            v
        }),
        ("garbage", |b| vec![0x5A; b.len().min(64)]),
    ];
    for (tag, mutate) in cases {
        let files = entry_files(&dir);
        std::fs::write(&files[0], mutate(&pristine)).unwrap();
        let cache = WorkloadCache::new(4).with_disk(store_at(&dir));
        let (_, fetch) = cache.get_or_build(&k).unwrap_or_else(|e| {
            panic!("{tag}: corrupt entry must rebuild, not fail: {e}")
        });
        assert_eq!(fetch, Fetch::Built, "{tag}: must rebuild, not trust the corpse");
        let c = cache.counters();
        assert_eq!((c.disk_hits, c.disk_misses), (0, 1), "{tag}");
        // The rebuild re-persisted a valid entry.
        let files = entry_files(&dir);
        let healed = std::fs::read(&files[0]).unwrap();
        assert_eq!(healed, pristine, "{tag}: deterministic build re-persists identically");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two caches over two stores (≈ two processes) racing on one key: the
/// per-key flock serializes them, so exactly one compiles and the other
/// loads the winner's entry.
#[test]
fn concurrent_processes_build_a_key_exactly_once() {
    let dir = tmp_dir("two-procs");
    let caches: Vec<Arc<WorkloadCache>> = (0..2)
        .map(|_| Arc::new(WorkloadCache::new(4).with_disk(store_at(&dir))))
        .collect();
    let barrier = Arc::new(std::sync::Barrier::new(caches.len()));
    let handles: Vec<_> = caches
        .iter()
        .map(|cache| {
            let cache = cache.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                cache.get_or_build(&key(1)).unwrap().1
            })
        })
        .collect();
    let fetches: Vec<Fetch> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(fetches.iter().filter(|f| **f == Fetch::Built).count(), 1, "{fetches:?}");
    assert_eq!(fetches.iter().filter(|f| **f == Fetch::DiskHit).count(), 1, "{fetches:?}");
    let total_builds: u64 = caches.iter().map(|c| c.counters().builds()).sum();
    let total_disk_hits: u64 = caches.iter().map(|c| c.counters().disk_hits).sum();
    assert_eq!((total_builds, total_disk_hits), (1, 1));
    assert_eq!(entry_files(&dir).len(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_respects_the_size_bound_and_evicts_oldest_first() {
    let dir = tmp_dir("gc");
    let unbounded = store_at(&dir);
    let keys = [key(1), key(2), key(4)];
    let mut sizes = Vec::new();
    for k in &keys {
        sizes.push(unbounded.store(k, &k.build()).unwrap());
        // Distinct mtimes so eviction order is well-defined.
        std::thread::sleep(std::time::Duration::from_millis(30));
    }
    let total: u64 = sizes.iter().sum();
    assert_eq!(unbounded.bytes_on_disk(), total);
    assert_eq!(entry_files(&dir).len(), 3);

    // A bound just below the total must evict exactly the oldest entry.
    let bound = total - 1;
    let bounded_cfg = DiskConfig { dir: dir.clone(), max_bytes: bound };
    let bounded = Arc::new(DiskStore::open(bounded_cfg).unwrap());
    let evicted = bounded.gc();
    assert_eq!(evicted, sizes[0], "oldest entry evicted first");
    assert!(bounded.bytes_on_disk() <= bound);
    let survivors = entry_files(&dir);
    assert_eq!(survivors.len(), 2);
    let cache = WorkloadCache::new(4).with_disk(bounded.clone());
    assert_eq!(cache.get_or_build(&keys[0]).unwrap().1, Fetch::Built, "victim rebuilds");
    assert_eq!(cache.get_or_build(&keys[2]).unwrap().1, Fetch::DiskHit, "newest survived");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stats_and_clear_see_the_same_entries_the_service_wrote() {
    let dir = tmp_dir("stats");
    let cfg = ServiceConfig {
        workers: 1,
        disk: Some(DiskConfig::new(&dir)),
        ..ServiceConfig::default()
    };
    let service = Service::start(cfg);
    let spec = RunSpec::new(
        BenchPoint::new(KernelKind::Sddmm, DatasetKind::PubMed, 1, 0.04),
        Variant::Baseline,
    );
    let _ = service.run_batch(std::slice::from_ref(&spec));
    drop(service);
    let store = store_at(&dir);
    let s = store.stats();
    assert_eq!(s.entries, 1);
    assert!(s.bytes > 0);
    assert_eq!(s.versions, vec![(CODEC_VERSION, 1)]);
    assert_eq!(s.unreadable, 0);
    assert_eq!(store.clear().unwrap(), 1);
    assert_eq!(store.stats().entries, 0);
    let _ = std::fs::remove_dir_all(&dir);
}
