//! Integration tests for the on-disk workload tier (`service::disk`):
//! warm-restart reuse through a whole `Service`, the v2 compressed
//! codec (property-tested over `util::prop`-generated workloads and a
//! fault-injection corruption matrix), the read-only seed tier and its
//! invariants under concurrent GC, cross-"process" build coordination
//! via the per-key file lock, the size-bounded GC with its dry-run
//! report, and the held-lock `clear()` regression.

use dare::coordinator::{BenchPoint, RunSpec};
use dare::isa::{Csr, MInstr, MReg, Program, NUM_MREGS};
use dare::kernels::{KernelKind, RegionCheck, Workload, WorkloadKey};
use dare::service::disk::{self, CODEC_V1, CODEC_VERSION, HEADER_LEN, MAX_RUN};
use dare::service::{DiskConfig, DiskStore, Fetch, Service, ServiceConfig, WorkloadCache};
use dare::sim::{MemImage, Variant};
use dare::sparse::DatasetKind;
use dare::util::prop::{self, Gen};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::SystemTime;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dare-e2e-disk-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn key(block: usize) -> WorkloadKey {
    WorkloadKey::new(KernelKind::Sddmm, DatasetKind::PubMed, block, false, 0.04)
}

fn store_at(dir: &Path) -> Arc<DiskStore> {
    Arc::new(DiskStore::open(DiskConfig::new(dir)).unwrap())
}

fn seeded_store(writable: &Path, seed: &Path) -> Arc<DiskStore> {
    Arc::new(DiskStore::open(DiskConfig::new(writable).with_seed(seed)).unwrap())
}

fn entry_path(dir: &Path, k: &WorkloadKey) -> PathBuf {
    dir.join(format!("{}.dwl", k.cache_file_stem()))
}

fn entry_files(dir: &Path) -> Vec<PathBuf> {
    let mut v: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|s| s.to_str()) == Some("dwl"))
        .collect();
    v.sort();
    v
}

/// `(name, content, mtime)` of every file in `dir` — the "nothing here
/// may ever change" witness for seed-tier invariants.
fn dir_snapshot(dir: &Path) -> Vec<(String, Vec<u8>, SystemTime)> {
    let mut v: Vec<(String, Vec<u8>, SystemTime)> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .map(|e| {
            let name = e.file_name().to_string_lossy().into_owned();
            let content = std::fs::read(e.path()).unwrap();
            let mtime = e.metadata().unwrap().modified().unwrap();
            (name, content, mtime)
        })
        .collect();
    v.sort();
    v
}

fn assert_same_workload(a: &Workload, b: &Workload) {
    assert_eq!(a.kind.name(), b.kind.name());
    assert_eq!(a.program.name, b.program.name);
    assert_eq!(a.program.instrs, b.program.instrs);
    assert_eq!(a.program.useful_macs, b.program.useful_macs);
    assert_eq!(a.program.issued_macs, b.program.issued_macs);
    assert_eq!(a.program.mem_high_water, b.program.mem_high_water);
    assert_eq!(a.mem.len(), b.mem.len());
    assert_eq!(a.mem.read_bytes(0, a.mem.len()), b.mem.read_bytes(0, b.mem.len()));
    assert_eq!(a.checks.len(), b.checks.len());
    for (ca, cb) in a.checks.iter().zip(&b.checks) {
        assert_eq!(ca.name, cb.name);
        assert_eq!(ca.addr, cb.addr);
        assert_eq!(ca.expect, cb.expect);
    }
}

// ---------------------------------------------------------------------
// Generators (over util::prop) for the codec property suite
// ---------------------------------------------------------------------

fn gen_mreg(g: &mut Gen) -> MReg {
    MReg(g.usize_in(0, NUM_MREGS) as u8)
}

fn gen_instr(g: &mut Gen) -> MInstr {
    match g.usize_in(0, 6) {
        0 => MInstr::Mcfg {
            csr: *g.pick(&[Csr::MatrixM, Csr::MatrixK, Csr::MatrixN]),
            val: g.u32(),
        },
        1 => MInstr::Mld { md: gen_mreg(g), base: g.u64(), stride: g.u64() },
        2 => MInstr::Mst { ms3: gen_mreg(g), base: g.u64(), stride: g.u64() },
        3 => MInstr::Mma { md: gen_mreg(g), ms1: gen_mreg(g), ms2: gen_mreg(g) },
        4 => MInstr::Mgather { md: gen_mreg(g), ms1: gen_mreg(g) },
        _ => MInstr::Mscatter { ms2: gen_mreg(g), ms1: gen_mreg(g) },
    }
}

/// A synthetic workload with a `zero_fraction`-sparse memory image of
/// `mem_len` bytes — every field the codec serializes is randomized.
fn gen_workload(g: &mut Gen, mem_len: usize, zero_fraction: f64) -> Workload {
    let mut mem = MemImage::new(mem_len);
    if mem_len > 0 {
        let bytes = g.sparse_bytes(mem_len, zero_fraction);
        mem.write_bytes(0, &bytes);
    }
    let n_instrs = g.usize_in(0, 65);
    let instrs = (0..n_instrs).map(|_| gen_instr(g)).collect();
    let n_checks = g.usize_in(0, 4);
    let checks = (0..n_checks)
        .map(|_| {
            let n = g.usize_in(0, 16);
            RegionCheck { name: g.ident(12), addr: g.u64(), expect: g.vec_f32(n) }
        })
        .collect();
    Workload {
        kind: *g.pick(&KernelKind::ALL),
        program: Program {
            name: g.ident(24),
            instrs,
            useful_macs: g.u64(),
            issued_macs: g.u64(),
            mem_high_water: g.u64(),
        },
        mem,
        checks,
    }
}

/// A raw v2 frame with an arbitrary (possibly hostile) header.
fn v2_frame(checksum: u64, body_len: u64, payload: &[u8]) -> Vec<u8> {
    disk::frame(CODEC_VERSION, checksum, body_len, payload)
}

// ---------------------------------------------------------------------
// v2 codec property suite
// ---------------------------------------------------------------------

#[test]
fn prop_v2_codec_round_trips_generated_workloads() {
    prop::run("v2-roundtrip", 40, |g| {
        let zero_fraction = g.f64();
        let mem_len = g.usize_in(0, 1 << 15);
        let w = gen_workload(g, mem_len, zero_fraction);
        let k = key(1);
        let bytes = disk::encode(&k, &w);
        let back = disk::decode(&k, &bytes).expect("v2 round trip decode");
        assert_same_workload(&w, &back);
        // The retained v1 reference codec agrees on the same workload.
        let v1 = disk::encode_v1(&k, &w);
        let (b1, ver) = disk::decode_versioned(&k, &v1).expect("v1 decode");
        assert_eq!(ver, CODEC_V1);
        assert_same_workload(&w, &b1);
    });
}

#[test]
fn prop_v2_codec_round_trips_edge_images() {
    prop::run("v2-edges", 30, |g| {
        // Image lengths that stress the RLE chunking: empty, tiny,
        // straddling MAX_RUN, multi-chunk max-length runs, and ordinary.
        let mem_len = match g.usize_in(0, 5) {
            0 => 0,
            1 => g.near(MAX_RUN, 2),
            2 => g.near(2 * MAX_RUN, 3),
            3 => g.size(64),
            _ => g.size(1 << 14),
        };
        for mode in 0..3 {
            let mut mem = MemImage::new(mem_len);
            match mode {
                // All-zero image: one giant (possibly split) zero run.
                0 => {}
                // Fully dense image: pure literals, no compressible run.
                1 => {
                    let b: Vec<u8> = (0..mem_len).map(|i| (i % 251) as u8 + 1).collect();
                    mem.write_bytes(0, &b);
                }
                // Mixed runs.
                _ => {
                    let b = g.sparse_bytes(mem_len, 0.7);
                    mem.write_bytes(0, &b);
                }
            }
            let w = Workload {
                kind: KernelKind::Sddmm,
                program: Program {
                    name: "edge".into(),
                    instrs: Vec::new(),
                    useful_macs: 0,
                    issued_macs: 0,
                    mem_high_water: 0,
                },
                mem,
                checks: Vec::new(),
            };
            let k = key(1);
            let back = disk::decode(&k, &disk::encode(&k, &w))
                .unwrap_or_else(|e| panic!("edge len {mem_len} mode {mode}: {e}"));
            assert_same_workload(&w, &back);
        }
    });
}

#[test]
fn prop_zero_heavy_entries_compress_at_least_4x() {
    prop::run("v2-compression", 15, |g| {
        let mem_len = 32 * 1024 + g.size(64 * 1024);
        let w = gen_workload(g, mem_len, 0.95);
        let k = key(1);
        let v2 = disk::encode(&k, &w).len();
        let v1 = disk::encode_v1(&k, &w).len();
        assert!(v2 * 4 <= v1, "compressed {v2} B vs raw {v1} B: zero-heavy must be >= 4x");
    });
}

// ---------------------------------------------------------------------
// v2 fault-injection matrix
// ---------------------------------------------------------------------

#[test]
fn v2_frame_corruption_matrix() {
    let k = key(1);
    let bytes = disk::encode(&k, &k.build());
    // Truncation mid-run: cut inside an op header and inside run data.
    for cut in [HEADER_LEN + 1, HEADER_LEN + 2, bytes.len() / 2, bytes.len() - 1] {
        assert!(disk::decode(&k, &bytes[..cut]).is_err(), "cut at {cut} must not decode");
    }
    // A run length that would overflow the declared body size must
    // error before producing a single byte — not OOM, not wrap.
    let hostile = v2_frame(0, 64, &[0x00, 0xFF, 0xFF]);
    let err = disk::decode(&k, &hostile).unwrap_err();
    assert!(err.contains("overflows"), "{err}");
    // A hostile declared body length is rejected before any allocation.
    let huge = v2_frame(0, u64::MAX, &[]);
    assert!(disk::decode(&k, &huge).unwrap_err().contains("sanity"));
    // Bit-flips anywhere in the compressed payload are caught: either
    // the RLE stream no longer parses, or the flip survives inflation
    // and the checksum over the *uncompressed* body rejects it.
    for i in (HEADER_LEN..bytes.len()).step_by(97) {
        let mut bad = bytes.clone();
        bad[i] ^= 0x10;
        assert!(disk::decode(&k, &bad).is_err(), "payload flip at {i} must not decode");
    }
}

#[test]
fn mixed_generation_store_serves_both_and_migrates_v1() {
    let dir = tmp_dir("mixed");
    let store = store_at(&dir);
    let (k1, k2) = (key(1), key(2));
    let w1 = k1.build();
    // A v1 entry left behind by an old binary, next to a fresh v2 one.
    std::fs::write(entry_path(&dir, &k1), disk::encode_v1(&k1, &w1)).unwrap();
    store.store(&k2, &k2.build()).unwrap();
    assert_eq!(store.stats().workloads.versions, vec![(CODEC_V1, 1), (CODEC_VERSION, 1)]);
    let cache = WorkloadCache::new(4).with_disk(store.clone());
    assert_eq!(cache.get_or_build(&k1).unwrap().1, Fetch::DiskHit, "v1 generation serves");
    assert_eq!(cache.get_or_build(&k2).unwrap().1, Fetch::DiskHit, "v2 generation serves");
    // The v1 hit was lazily rewritten in the current compressed format.
    assert_eq!(store.stats().workloads.versions, vec![(CODEC_VERSION, 2)], "lazy migration");
    // A corrupt legacy entry rebuilds cleanly instead of poisoning the
    // directory.
    let mut bad = disk::encode_v1(&k1, &w1);
    bad.truncate(bad.len() - 3);
    std::fs::write(entry_path(&dir, &k1), &bad).unwrap();
    let cache2 = WorkloadCache::new(4).with_disk(store_at(&dir));
    assert_eq!(cache2.get_or_build(&k1).unwrap().1, Fetch::Built);
    assert_eq!(store.stats().workloads.versions, vec![(CODEC_VERSION, 2)]);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Seed tier
// ---------------------------------------------------------------------

#[test]
fn seed_tier_serves_promotes_and_never_writes_the_seed() {
    let seed = tmp_dir("seed-src");
    let writable = tmp_dir("seed-writable");
    let k = key(1);
    DiskStore::open(DiskConfig::new(&seed)).unwrap().store(&k, &k.build()).unwrap();
    let before = dir_snapshot(&seed);

    let cache = WorkloadCache::new(4).with_disk(seeded_store(&writable, &seed));
    let (_, fetch) = cache.get_or_build(&k).unwrap();
    assert_eq!(fetch, Fetch::SeedHit);
    let c = cache.counters();
    assert_eq!((c.seed_hits, c.disk_hits, c.disk_misses, c.builds()), (1, 0, 0, 0));
    assert!((c.disk_hit_rate() - 1.0).abs() < 1e-9);
    assert!(c.compression_ratio() > 1.0, "ratio {}", c.compression_ratio());
    // Promoted into memory: the next lookup in this cache is a plain hit.
    assert_eq!(cache.get_or_build(&k).unwrap().1, Fetch::Hit);
    // Promoted into the writable tier: a fresh cache (≈ a new process)
    // hits the writable dir and never reaches the seed.
    assert_eq!(entry_files(&writable).len(), 1, "seed hit promoted to writable tier");
    let cache2 = WorkloadCache::new(4).with_disk(seeded_store(&writable, &seed));
    assert_eq!(cache2.get_or_build(&k).unwrap().1, Fetch::DiskHit);
    assert_eq!(cache2.counters().seed_hits, 0);
    // The read-only invariant: byte-for-byte and mtime-for-mtime, the
    // seed is exactly what it was.
    assert_eq!(dir_snapshot(&seed), before, "the seed must never be written or touched");
    let _ = std::fs::remove_dir_all(&seed);
    let _ = std::fs::remove_dir_all(&writable);
}

#[test]
fn corrupt_seed_entry_falls_through_to_build_without_poisoning() {
    let seed = tmp_dir("seed-corrupt-src");
    let writable = tmp_dir("seed-corrupt-writable");
    let k = key(1);
    let mut bad = disk::encode(&k, &k.build());
    bad.truncate(bad.len() - 11);
    std::fs::write(entry_path(&seed, &k), &bad).unwrap();
    let before = dir_snapshot(&seed);

    let cache = WorkloadCache::new(4).with_disk(seeded_store(&writable, &seed));
    let (_, fetch) = cache.get_or_build(&k).unwrap();
    assert_eq!(fetch, Fetch::Built, "corrupt seed entry must fall through to a build");
    let c = cache.counters();
    assert_eq!((c.seed_hits, c.disk_hits, c.disk_misses, c.builds()), (0, 0, 1, 1));
    // The corpse is left exactly as-is (read-only tier: no quarantine).
    assert_eq!(dir_snapshot(&seed), before, "corrupt seed entries are never deleted");
    // The build landed in the writable tier — healthy, not poisoned.
    let cache2 = WorkloadCache::new(4).with_disk(seeded_store(&writable, &seed));
    assert_eq!(cache2.get_or_build(&k).unwrap().1, Fetch::DiskHit);
    let _ = std::fs::remove_dir_all(&seed);
    let _ = std::fs::remove_dir_all(&writable);
}

/// Writable tier under concurrent GC while a read-only seed is mounted:
/// the seed is never written, never evicted, and a seed hit during
/// eviction still serves. The writable bound is 1 byte, so every
/// promotion is immediately evictable — maximum churn.
#[test]
fn concurrent_gc_never_touches_the_seed_and_seed_hits_still_serve() {
    let seed = tmp_dir("seed-gc-src");
    let writable = tmp_dir("seed-gc-writable");
    let keys = [key(1), key(2)];
    let builder = DiskStore::open(DiskConfig::new(&seed)).unwrap();
    for k in &keys {
        builder.store(k, &k.build()).unwrap();
    }
    let before = dir_snapshot(&seed);

    let cfg = DiskConfig { dir: writable.clone(), max_bytes: 1, seed: Some(seed.clone()) };
    let store = Arc::new(DiskStore::open(cfg).unwrap());
    let stop = Arc::new(AtomicBool::new(false));
    let gc_thread = {
        let store = store.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut sweeps = 0u64;
            while !stop.load(Ordering::Relaxed) {
                store.gc();
                sweeps += 1;
            }
            sweeps
        })
    };
    let loaders: Vec<_> = keys
        .iter()
        .copied()
        .map(|k| {
            let store = store.clone();
            std::thread::spawn(move || {
                for i in 0..25 {
                    let l = store
                        .load(&k)
                        .unwrap_or_else(|| panic!("load {i} of {} must serve", k.name()));
                    assert!(!l.workload.mem.is_empty());
                }
            })
        })
        .collect();
    for h in loaders {
        h.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let sweeps = gc_thread.join().unwrap();
    assert!(sweeps > 0, "GC must actually have raced the loads");
    assert_eq!(
        dir_snapshot(&seed),
        before,
        "concurrent GC/promotion must never write, touch, or evict the seed"
    );
    let _ = std::fs::remove_dir_all(&seed);
    let _ = std::fs::remove_dir_all(&writable);
}

/// The acceptance-criteria seed path end-to-end: a *service* over a
/// fresh writable tier + the previous run's cache as a read-only seed
/// compiles nothing and reports every build as a seed hit.
#[test]
fn seeded_service_compiles_nothing() {
    let seed = tmp_dir("seed-service-src");
    let writable = tmp_dir("seed-service-writable");
    let specs: Vec<RunSpec> = [Variant::Baseline, Variant::DareFre]
        .into_iter()
        .flat_map(|v| {
            [DatasetKind::PubMed, DatasetKind::Gpt2Attention]
                .into_iter()
                .map(move |d| RunSpec::new(BenchPoint::new(KernelKind::Sddmm, d, 1, 0.04), v))
        })
        .collect();
    // Build the seed with a plain --cache-dir run. The result tier is
    // off in both services so this test keeps exercising the *workload*
    // tier (result-tier replay would skip get_or_build entirely; the
    // result-tier seed path has its own test in tests/results.rs).
    let cold = Service::start(ServiceConfig {
        workers: 2,
        disk: Some(DiskConfig::new(&seed)),
        result_cache: false,
        ..ServiceConfig::default()
    });
    let cold_results = cold.run_batch(&specs);
    drop(cold);
    // Seeded run: fresh memory cache, fresh writable dir, read-only seed.
    let seeded = Service::start(ServiceConfig {
        workers: 2,
        disk: Some(DiskConfig::new(&writable).with_seed(&seed)),
        result_cache: false,
        ..ServiceConfig::default()
    });
    let seeded_results = seeded.run_batch(&specs);
    let c = seeded.metrics().cache;
    assert_eq!(c.seed_hits, 2, "one seed hit per unique workload");
    assert_eq!(c.disk_misses, 0);
    assert_eq!(c.builds(), 0, "a seeded run compiles nothing");
    assert!((c.disk_hit_rate() - 1.0).abs() < 1e-9);
    for (a, b) in cold_results.iter().zip(&seeded_results) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.stats.cycles, b.stats.cycles, "{}", a.name);
    }
    let _ = std::fs::remove_dir_all(&seed);
    let _ = std::fs::remove_dir_all(&writable);
}

// ---------------------------------------------------------------------
// Warm restart / corruption recovery / locking / GC (writable tier)
// ---------------------------------------------------------------------

/// The acceptance-criteria path end-to-end: a second *service* (≈ a
/// second `dare` process / a restarted `dare serve`) over the same
/// cache directory serves every unique workload from disk.
#[test]
fn warm_service_restart_hits_disk_for_every_unique_workload() {
    let dir = tmp_dir("warm-restart");
    let specs: Vec<RunSpec> = [Variant::Baseline, Variant::Nvr, Variant::DareFre]
        .into_iter()
        .flat_map(|v| {
            [DatasetKind::PubMed, DatasetKind::Gpt2Attention]
                .into_iter()
                .map(move |d| RunSpec::new(BenchPoint::new(KernelKind::Sddmm, d, 1, 0.04), v))
        })
        .collect();

    // Result memoization off: with it on, the warm run would replay
    // `.dsr` results and never probe the workload tier this test is
    // about (tests/results.rs covers the warm *result* path).
    let cold_cfg = ServiceConfig {
        workers: 2,
        disk: Some(DiskConfig::new(&dir)),
        result_cache: false,
        ..ServiceConfig::default()
    };
    let cold = Service::start(cold_cfg.clone());
    let cold_results = cold.run_batch(&specs);
    let c = cold.metrics().cache;
    assert_eq!(c.disk_hits, 0, "first run has nothing to reuse");
    assert_eq!(c.disk_misses, 2, "one probe per unique workload");
    assert!(c.bytes_on_disk > 0);
    assert!(c.compression_ratio() > 1.0, "stored entries are compressed");
    drop(cold);

    // "Restart": a brand-new service, empty memory cache, same dir.
    let warm = Service::start(cold_cfg);
    let warm_results = warm.run_batch(&specs);
    let c = warm.metrics().cache;
    assert_eq!(c.disk_hits, 2, "every unique workload loads from disk");
    assert_eq!(c.disk_misses, 0);
    assert_eq!(c.builds(), 0, "the warm run compiles nothing");
    assert!(
        c.disk_hit_rate() >= 0.9,
        "warm-restart disk hit rate {} below the CI bar",
        c.disk_hit_rate()
    );
    // Disk-served builds are exact: identical simulation results.
    for (a, b) in cold_results.iter().zip(&warm_results) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.stats.cycles, b.stats.cycles, "{}", a.name);
        assert_eq!(a.stats.instrs_retired, b.stats.instrs_retired, "{}", a.name);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_corruption_class_rebuilds_instead_of_panicking() {
    let dir = tmp_dir("corruption");
    let k = key(1);
    store_at(&dir).store(&k, &k.build()).unwrap();
    let pristine = std::fs::read(&entry_files(&dir)[0]).unwrap();

    // (tag, mutate) pairs covering: truncated payload, flipped payload
    // byte (structural or checksum failure), unknown codec version,
    // garbage header.
    type Mutate = fn(&[u8]) -> Vec<u8>;
    let cases: [(&str, Mutate); 4] = [
        ("truncated", |b| b[..b.len() - 9].to_vec()),
        ("bit-flip", |b| {
            let mut v = b.to_vec();
            let mid = HEADER_LEN + (v.len() - HEADER_LEN) / 2;
            v[mid] ^= 0x40;
            v
        }),
        ("future-version", |b| {
            let mut v = b.to_vec();
            let bumped = (CODEC_VERSION + 1).to_le_bytes();
            v[4] = bumped[0];
            v[5] = bumped[1];
            v
        }),
        ("garbage", |b| vec![0x5A; b.len().min(64)]),
    ];
    for (tag, mutate) in cases {
        let files = entry_files(&dir);
        std::fs::write(&files[0], mutate(&pristine)).unwrap();
        let cache = WorkloadCache::new(4).with_disk(store_at(&dir));
        let (_, fetch) = cache.get_or_build(&k).unwrap_or_else(|e| {
            panic!("{tag}: corrupt entry must rebuild, not fail: {e}")
        });
        assert_eq!(fetch, Fetch::Built, "{tag}: must rebuild, not trust the corpse");
        let c = cache.counters();
        assert_eq!((c.disk_hits, c.disk_misses), (0, 1), "{tag}");
        // The rebuild re-persisted a valid entry.
        let files = entry_files(&dir);
        let healed = std::fs::read(&files[0]).unwrap();
        assert_eq!(healed, pristine, "{tag}: deterministic build re-persists identically");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two caches over two stores (≈ two processes) racing on one key: the
/// per-key flock serializes them, so exactly one compiles and the other
/// loads the winner's entry.
#[test]
fn concurrent_processes_build_a_key_exactly_once() {
    let dir = tmp_dir("two-procs");
    let caches: Vec<Arc<WorkloadCache>> = (0..2)
        .map(|_| Arc::new(WorkloadCache::new(4).with_disk(store_at(&dir))))
        .collect();
    let barrier = Arc::new(std::sync::Barrier::new(caches.len()));
    let handles: Vec<_> = caches
        .iter()
        .map(|cache| {
            let cache = cache.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                cache.get_or_build(&key(1)).unwrap().1
            })
        })
        .collect();
    let fetches: Vec<Fetch> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(fetches.iter().filter(|f| **f == Fetch::Built).count(), 1, "{fetches:?}");
    assert_eq!(fetches.iter().filter(|f| **f == Fetch::DiskHit).count(), 1, "{fetches:?}");
    let total_builds: u64 = caches.iter().map(|c| c.counters().builds()).sum();
    let total_disk_hits: u64 = caches.iter().map(|c| c.counters().disk_hits).sum();
    assert_eq!((total_builds, total_disk_hits), (1, 1));
    assert_eq!(entry_files(&dir).len(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Regression: `clear()` must skip lock files whose flock is currently
/// held. Unlinking a held lock lets the next process lock a fresh inode
/// while the builder still holds the old one — two "exclusive" builders.
#[test]
fn clear_skips_lock_files_held_by_a_live_builder() {
    let dir = tmp_dir("clear-lock");
    let a = store_at(&dir);
    let b = store_at(&dir);
    let k = key(1);
    a.store(&k, &k.build()).unwrap();
    let guard = a.lock(&k).expect("builder lock");
    // A second store (≈ a concurrent `dare cache clear`) wipes the dir.
    assert_eq!(b.clear().unwrap(), 1, "the entry itself is removed");
    let lock_path = dir.join(format!("{}.lock", k.cache_file_stem()));
    if cfg!(unix) {
        assert!(lock_path.exists(), "held lock file must survive clear");
        // The single-builder guarantee still holds through the original
        // inode: a third party cannot take the lock.
        assert!(b.try_lock(&k).is_none(), "lock must still be exclusively held");
    }
    drop(guard);
    // With the builder gone the lock is reapable and takeable again.
    b.clear().unwrap();
    assert!(!lock_path.exists(), "released lock file is reaped by the next clear");
    assert!(b.try_lock(&k).is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_respects_the_size_bound_and_evicts_oldest_first() {
    let dir = tmp_dir("gc");
    let unbounded = store_at(&dir);
    let keys = [key(1), key(2), key(4)];
    let mut sizes = Vec::new();
    for k in &keys {
        sizes.push(unbounded.store(k, &k.build()).unwrap().stored_bytes);
        // Distinct mtimes so eviction order is well-defined.
        std::thread::sleep(std::time::Duration::from_millis(30));
    }
    let total: u64 = sizes.iter().sum();
    assert_eq!(unbounded.bytes_on_disk(), total);
    assert_eq!(entry_files(&dir).len(), 3);

    // A bound just below the total: dry-run first — it must name
    // exactly the oldest entry and delete nothing.
    let bound = total - 1;
    let bounded_cfg = DiskConfig { dir: dir.clone(), max_bytes: bound, seed: None };
    let bounded = Arc::new(DiskStore::open(bounded_cfg).unwrap());
    let plan = bounded.gc_with(bound, true);
    assert!(plan.dry_run);
    assert_eq!(plan.victims.len(), 1, "{plan:?}");
    assert_eq!(plan.victims[0].1, sizes[0], "oldest entry is the victim");
    assert_eq!(entry_files(&dir).len(), 3, "dry run deletes nothing");
    // The live sweep evicts exactly that entry.
    let evicted = bounded.gc();
    assert_eq!(evicted, sizes[0], "oldest entry evicted first");
    assert!(bounded.bytes_on_disk() <= bound);
    let survivors = entry_files(&dir);
    assert_eq!(survivors.len(), 2);
    let cache = WorkloadCache::new(4).with_disk(bounded.clone());
    assert_eq!(cache.get_or_build(&keys[0]).unwrap().1, Fetch::Built, "victim rebuilds");
    assert_eq!(cache.get_or_build(&keys[2]).unwrap().1, Fetch::DiskHit, "newest survived");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stats_and_clear_see_the_same_entries_the_service_wrote() {
    let dir = tmp_dir("stats");
    let cfg = ServiceConfig {
        workers: 1,
        disk: Some(DiskConfig::new(&dir)),
        ..ServiceConfig::default()
    };
    let service = Service::start(cfg);
    let spec = RunSpec::new(
        BenchPoint::new(KernelKind::Sddmm, DatasetKind::PubMed, 1, 0.04),
        Variant::Baseline,
    );
    let _ = service.run_batch(std::slice::from_ref(&spec));
    drop(service);
    let store = store_at(&dir);
    let s = store.stats();
    // One `.dwl` workload entry *and* one `.dsr` result entry, reported
    // per tier — the `dare cache stats` split.
    assert_eq!(s.workloads.entries, 1);
    assert_eq!(s.results.entries, 1, "the sim result is persisted beside the workload");
    assert!(s.workloads.bytes > 0);
    assert!(s.results.bytes > 0);
    assert_eq!(s.workloads.versions, vec![(CODEC_VERSION, 1)]);
    assert_eq!(s.results.versions, vec![(CODEC_VERSION, 1)]);
    assert_eq!(s.workloads.unreadable + s.results.unreadable, 0);
    assert_eq!(s.entries(), 2);
    assert_eq!(s.bytes(), s.workloads.bytes + s.results.bytes);
    assert_eq!(store.clear().unwrap(), 2, "clear removes both tiers' entries");
    assert_eq!(store.stats().entries(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}
