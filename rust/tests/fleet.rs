//! Integration tests for the sharded serve fleet: router + real worker
//! subprocesses (the compiled `dare` binary), exactly-once delivery
//! across a SIGKILL'd worker, and the router-side auth handshake.

use dare::service::fleet::{Fleet, FleetConfig};
use dare::service::transport::{Listener, Stream};
use dare::service::Json;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;

extern "C" {
    fn kill(pid: i32, sig: i32) -> i32;
}
const SIGKILL: i32 = 9;

/// A scratch directory for one test's sockets + shared cache dir.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dare-fleet-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create fleet scratch dir");
    dir
}

fn job_line(id: &str, kernel: &str, variant: &str, block: usize) -> String {
    format!(
        "{{\"id\":\"{id}\",\"kernel\":\"{kernel}\",\"dataset\":\"pubmed\",\
         \"variant\":\"{variant}\",\"block\":{block},\"scale\":0.04}}"
    )
}

#[test]
fn fleet_survives_worker_sigkill_mid_batch() {
    let dir = scratch("sigkill");
    let router_sock = dir.join("router.sock");
    let cache_dir = dir.join("cache");
    std::fs::create_dir_all(&cache_dir).unwrap();

    let mut cfg = FleetConfig::new(2, env!("CARGO_BIN_EXE_dare"), &dir);
    // Shared cache dir: a re-routed job that already ran on the dead
    // shard is a disk hit on the shard that picks it up.
    cfg.worker_args = vec![
        "--threads".into(),
        "1".into(),
        "--cache-dir".into(),
        cache_dir.display().to_string(),
    ];
    let listener = Listener::bind_unix(router_sock.to_str().unwrap()).expect("bind router");
    let fleet = Fleet::launch(cfg, listener).expect("launch fleet");
    let pids = fleet.worker_pids();
    assert_eq!(pids.len(), 2);
    let victim = pids.iter().flatten().next().copied().expect("a live worker pid") as i32;

    let mut stream = Stream::connect_unix(router_sock.to_str().unwrap()).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "{{\"cmd\":\"hello\",\"proto\":2}}").unwrap();
    // Pipelined batch across both kernels and blocks so the keys spread
    // over the ring; duplicate specs under fresh ids are cache hits.
    let mut want_ids = Vec::new();
    let mut i = 0;
    for rep in 0..2 {
        for kernel in ["sddmm", "spmm"] {
            for variant in ["baseline", "dare-full"] {
                for block in [1usize, 2] {
                    let id = format!("f/{rep}/{i}");
                    writeln!(stream, "{}", job_line(&id, kernel, variant, block)).unwrap();
                    want_ids.push(id);
                    i += 1;
                }
            }
        }
    }
    stream.flush().unwrap();
    let n = want_ids.len() as u64; // 16

    // SIGKILL one worker while the batch is in flight. The router must
    // detect the death, re-route that shard's pending jobs, restart the
    // worker — and still answer every job exactly once.
    assert_eq!(unsafe { kill(victim, SIGKILL) }, 0, "kill worker {victim}");
    writeln!(stream, "{{\"cmd\":\"done\"}}").unwrap();
    stream.flush().unwrap();

    let mut answered: HashMap<String, u64> = HashMap::new();
    let mut line = String::new();
    let done_metrics = loop {
        line.clear();
        let got = reader.read_line(&mut line).expect("read event line");
        assert!(got > 0, "router closed the stream before done");
        let v = Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
        match v.get("event").and_then(Json::as_str) {
            Some("result") => {
                assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{line}");
                let id = v.get("id").and_then(Json::as_str).expect("id echoed").to_string();
                *answered.entry(id).or_insert(0) += 1;
            }
            Some("busy") => {}
            Some("hello") => {}
            Some("done") => break v.get("metrics").expect("done carries metrics").clone(),
            other => panic!("unexpected event {other:?} in {line:?}"),
        }
    };
    // Exactly once: every id answered, none answered twice.
    assert_eq!(answered.len(), want_ids.len(), "{answered:?}");
    for id in &want_ids {
        assert_eq!(answered.get(id), Some(&1), "job {id} lost or duplicated");
    }
    assert_eq!(done_metrics.get("jobs").and_then(Json::as_u64), Some(n));
    assert_eq!(done_metrics.get("failed").and_then(Json::as_u64), Some(0));

    // A second connection polls the router metrics: the failover is
    // visible, and the ring is fully repopulated (restart).
    let mut probe = Stream::connect_unix(router_sock.to_str().unwrap()).expect("connect probe");
    let mut probe_reader = BufReader::new(probe.try_clone().unwrap());
    writeln!(probe, "{{\"cmd\":\"hello\",\"proto\":2}}").unwrap();
    writeln!(probe, "{{\"cmd\":\"metrics\"}}").unwrap();
    probe.flush().unwrap();
    let mut line = String::new();
    probe_reader.read_line(&mut line).expect("read hello reply");
    let v = Json::parse(line.trim()).unwrap();
    assert_eq!(v.get("event").and_then(Json::as_str), Some("hello"), "{line:?}");
    line.clear();
    probe_reader.read_line(&mut line).expect("read metrics");
    let v = Json::parse(line.trim()).unwrap();
    assert_eq!(v.get("event").and_then(Json::as_str), Some("metrics"), "{line:?}");
    let router = v.get("router").expect("router snapshot");
    assert!(
        router.get("failovers").and_then(Json::as_u64).unwrap() >= 1,
        "SIGKILL must register as a failover: {line}"
    );
    assert_eq!(
        router.get("jobs_routed").and_then(Json::as_u64).map(|r| r >= n),
        Some(true),
        "{line}"
    );
    writeln!(probe, "{{\"cmd\":\"shutdown\"}}").unwrap();
    probe.flush().unwrap();

    let final_metrics = fleet.join();
    let v = Json::parse(&final_metrics).unwrap();
    assert_eq!(v.get("workers").and_then(Json::as_u64), Some(2));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fleet_auth_requires_hello_handshake() {
    let dir = scratch("auth");
    let router_sock = dir.join("router.sock");
    let mut cfg = FleetConfig::new(1, env!("CARGO_BIN_EXE_dare"), &dir);
    cfg.auth = Some("fleet-secret".into());
    cfg.worker_args = vec!["--threads".into(), "1".into()];
    let listener = Listener::bind_unix(router_sock.to_str().unwrap()).expect("bind router");
    let fleet = Fleet::launch(cfg, listener).expect("launch fleet");

    // No hello: one unauthorized error frame, then the router closes the
    // session without routing anything.
    let mut bad = Stream::connect_unix(router_sock.to_str().unwrap()).expect("connect");
    let mut bad_reader = BufReader::new(bad.try_clone().unwrap());
    writeln!(bad, "{}", job_line("bad/0", "sddmm", "baseline", 1)).unwrap();
    bad.flush().unwrap();
    bad.shutdown_write();
    let mut lines = Vec::new();
    loop {
        let mut line = String::new();
        if bad_reader.read_line(&mut line).expect("read rejection") == 0 {
            break;
        }
        lines.push(line.trim().to_string());
    }
    assert_eq!(lines.len(), 1, "error then close: {lines:?}");
    let v = Json::parse(&lines[0]).unwrap();
    assert_eq!(v.get("event").and_then(Json::as_str), Some("error"));
    assert_eq!(v.get("code").and_then(Json::as_str), Some("unauthorized"));

    // Correct hello: handshake acknowledged, the job routes and answers.
    let mut good = Stream::connect_unix(router_sock.to_str().unwrap()).expect("connect");
    let mut good_reader = BufReader::new(good.try_clone().unwrap());
    writeln!(good, "{{\"cmd\":\"hello\",\"proto\":2,\"auth\":\"fleet-secret\"}}").unwrap();
    writeln!(good, "{}", job_line("good/0", "sddmm", "baseline", 1)).unwrap();
    writeln!(good, "{{\"cmd\":\"done\"}}").unwrap();
    good.flush().unwrap();
    let mut line = String::new();
    good_reader.read_line(&mut line).expect("read hello reply");
    let v = Json::parse(line.trim()).unwrap();
    assert_eq!(v.get("event").and_then(Json::as_str), Some("hello"), "{line:?}");
    assert_eq!(v.get("proto").and_then(Json::as_u64), Some(2));
    let mut results = 0;
    let done_metrics = loop {
        let mut line = String::new();
        assert!(good_reader.read_line(&mut line).expect("read event") > 0, "closed early");
        let v = Json::parse(line.trim()).unwrap();
        match v.get("event").and_then(Json::as_str) {
            Some("result") => {
                assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{line}");
                assert_eq!(v.get("id").and_then(Json::as_str), Some("good/0"));
                results += 1;
            }
            Some("busy") => {}
            Some("done") => break v.get("metrics").unwrap().clone(),
            other => panic!("unexpected event {other:?} in {line:?}"),
        }
    };
    assert_eq!(results, 1);
    assert_eq!(done_metrics.get("jobs").and_then(Json::as_u64), Some(1));
    assert_eq!(done_metrics.get("failed").and_then(Json::as_u64), Some(0));

    fleet.shutdown_handle().store(true, std::sync::atomic::Ordering::SeqCst);
    fleet.join();
    let _ = std::fs::remove_dir_all(&dir);
}
