//! Integration tests for the `dare::service` subsystem: cache-key
//! properties, JSONL protocol round-trips, in-flight build dedup, and
//! spec-order result delivery.

use dare::coordinator::{run_one, BenchPoint, RunSpec};
use dare::kernels::{KernelKind, WorkloadKey};
use dare::service::{JobRequest, JobResponse, Service, ServiceConfig};
use dare::sim::Variant;
use dare::sparse::DatasetKind;
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

fn tiny(kernel: KernelKind, dataset: DatasetKind, variant: Variant) -> RunSpec {
    RunSpec::new(BenchPoint::new(kernel, dataset, 1, 0.04), variant)
}

fn hash_of(key: &WorkloadKey) -> u64 {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    h.finish()
}

#[test]
fn cache_key_equality_and_hash_properties() {
    let base = WorkloadKey::new(KernelKind::SpMM, DatasetKind::PubMed, 8, true, 0.25);
    let same = WorkloadKey::new(KernelKind::SpMM, DatasetKind::PubMed, 8, true, 0.25);
    assert_eq!(base, same);
    assert_eq!(hash_of(&base), hash_of(&same), "equal keys must hash equally");

    // Every single-field perturbation must change the key.
    let perturbed = [
        WorkloadKey::new(KernelKind::Sddmm, DatasetKind::PubMed, 8, true, 0.25),
        WorkloadKey::new(KernelKind::SpMM, DatasetKind::Gpt2Attention, 8, true, 0.25),
        WorkloadKey::new(KernelKind::SpMM, DatasetKind::PubMed, 1, true, 0.25),
        WorkloadKey::new(KernelKind::SpMM, DatasetKind::PubMed, 8, false, 0.25),
        WorkloadKey::new(KernelKind::SpMM, DatasetKind::PubMed, 8, true, 0.26),
    ];
    for other in &perturbed {
        assert_ne!(base, *other);
    }

    // Keys work as HashMap keys: insert-then-lookup with a fresh equal
    // key, no collisions among the perturbations.
    let mut map = std::collections::HashMap::new();
    map.insert(base, "base");
    for (i, other) in perturbed.iter().enumerate() {
        map.insert(*other, "other");
        assert_eq!(map.len(), i + 2);
    }
    let fresh = WorkloadKey::new(KernelKind::SpMM, DatasetKind::PubMed, 8, true, 0.25);
    assert_eq!(map.get(&fresh), Some(&"base"));
}

#[test]
fn cache_key_derives_from_spec_variant() {
    let p = BenchPoint::new(KernelKind::Sddmm, DatasetKind::PubMed, 1, 0.04);
    // Strided variants share a key; densified variants share the other.
    let strided: Vec<WorkloadKey> = [Variant::Baseline, Variant::Nvr, Variant::DareFre]
        .iter()
        .map(|&v| RunSpec::new(p, v).workload_key())
        .collect();
    let densified: Vec<WorkloadKey> = [Variant::DareGsa, Variant::DareFull]
        .iter()
        .map(|&v| RunSpec::new(p, v).workload_key())
        .collect();
    assert!(strided.windows(2).all(|w| w[0] == w[1]));
    assert!(densified.windows(2).all(|w| w[0] == w[1]));
    assert_ne!(strided[0], densified[0]);
}

#[test]
fn jsonl_protocol_round_trip_job_to_result() {
    // job line → spec → (simulated) → outcome → result line → parse.
    let mut req = JobRequest::new(KernelKind::Sddmm, DatasetKind::PubMed, Variant::DareFull);
    req.id = Some("rt/0".into());
    req.scale = 0.04;
    req.verify = true;
    let parsed = JobRequest::parse(&req.to_json()).expect("request round-trip");
    assert_eq!(parsed, req);

    let spec = parsed.to_spec();
    let service = Service::start(ServiceConfig::with_workers(1));
    let outcomes = service.run_batch_outcomes(std::slice::from_ref(&spec));
    let response = JobResponse::from_outcome(parsed.id.clone(), &spec.name(), &outcomes[0]);
    let line = response.to_json();
    let reparsed = JobResponse::parse(&line).expect("response round-trip");
    assert_eq!(reparsed, response);
    assert!(reparsed.ok, "{line}");
    assert_eq!(reparsed.id.as_deref(), Some("rt/0"));
    assert_eq!(reparsed.name, spec.name());
    assert!(reparsed.cycles > 0);
    assert!(reparsed.verify_err.unwrap() < 1e-3);
}

#[test]
fn n_identical_specs_build_once() {
    let service = Service::start(ServiceConfig::with_workers(4));
    let specs: Vec<RunSpec> =
        (0..8).map(|_| tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::DareFre)).collect();
    let results = service.run_batch(&specs);
    assert_eq!(results.len(), 8);
    // Deterministic simulator + shared build → identical cycle counts.
    assert!(results.windows(2).all(|w| w[0].stats.cycles == w[1].stats.cycles));
    let m = service.metrics();
    let counters = m.cache;
    assert_eq!(counters.builds(), 1, "8 identical queued specs must build exactly once");
    // Every job past the first was served by reuse: either a memory /
    // coalesced hit on the workload build, or an in-process replay of
    // the memoized simulation result. How the 7 split between the two
    // depends on worker interleaving; the sum does not.
    assert_eq!(
        counters.hits + counters.coalesced + counters.result_hits,
        7,
        "{counters:?}"
    );
    // Without a disk tier every job probes the result memo exactly once:
    // replays hit, the rest simulate.
    assert_eq!(counters.result_hits + counters.result_misses, 8, "{counters:?}");
    assert_eq!(m.sims, counters.result_misses, "every memo miss simulates");
    assert!(m.sims >= 1, "at least the first job must simulate");
}

#[test]
fn service_results_match_run_one_in_spec_order() {
    let specs = vec![
        tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::Baseline),
        tiny(KernelKind::SpMM, DatasetKind::PubMed, Variant::DareFull),
        tiny(KernelKind::Sddmm, DatasetKind::Gpt2Attention, Variant::Nvr),
    ];
    let service = Service::start(ServiceConfig::with_workers(3));
    let batch = service.run_batch(&specs);
    for (spec, from_service) in specs.iter().zip(&batch) {
        let direct = run_one(spec, false);
        assert_eq!(from_service.name, direct.name, "spec order preserved");
        assert_eq!(
            from_service.stats.cycles, direct.stats.cycles,
            "cache-shared build must not change results for {}",
            direct.name
        );
    }
}

#[test]
fn metrics_snapshot_reflects_batch() {
    let service = Service::start(ServiceConfig::with_workers(2));
    let specs: Vec<RunSpec> = vec![
        tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::Baseline),
        tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::DareFre),
    ];
    let _ = service.run_batch(&specs);
    let m = service.metrics();
    assert_eq!(m.jobs_submitted, 2);
    assert_eq!(m.jobs_completed, 2);
    assert_eq!(m.jobs_failed, 0);
    assert_eq!(m.queue_depth, 0, "batch drained");
    assert_eq!(m.worker_busy.len(), 2);
    assert!(m.sim_cycles > 0);
    assert!(m.jobs_per_sec() > 0.0);
    assert!(m.worker_utilization() > 0.0);
    // The printable form carries the headline numbers.
    let text = format!("{m}");
    assert!(text.contains("2 jobs"), "{text}");
    assert!(text.contains("hit rate"), "{text}");
}
