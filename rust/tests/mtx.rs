//! Hostile-input property suite for the MatrixMarket loader
//! (`sparse::mtx`). The parser sits on the service's job-intake path
//! (`{"dataset":"file:…"}`), so its inputs are untrusted by definition:
//! the properties here hold it to "typed `MtxError` or a valid matrix,
//! never a panic" — every `parse_mtx` call runs under `catch_unwind` so
//! a panic is reported as the property violation it is, with the
//! offending input attached.
//!
//! Mirrors the fault-matrix idiom of `tests/disk.rs`: a generator for
//! *valid* files, a catalogue of byte- and token-level mutations that
//! turn them hostile, and seeded `util::prop` runs over both.

use dare::sparse::mtx::{parse_mtx, register_text, MtxError, MAX_DIM, MAX_NNZ};
use dare::sparse::Csc;
use dare::util::prop::{self, Gen};
use std::panic::{catch_unwind, AssertUnwindSafe};

// ---------------------------------------------------------------------
// Harness: parse under catch_unwind, never accept a panic
// ---------------------------------------------------------------------

/// Parse `text`, converting a parser panic into a test failure that
/// carries the hostile input. Returns the parser's typed verdict.
fn parse_no_panic(text: &str) -> Result<Csc, MtxError> {
    match catch_unwind(AssertUnwindSafe(|| parse_mtx(text))) {
        Ok(verdict) => verdict,
        Err(_) => panic!("parse_mtx panicked on hostile input:\n---\n{text}\n---"),
    }
}

/// The invariant every input must satisfy: no panic, and on `Ok` the
/// matrix is structurally valid and within the loader's sanity bounds.
fn assert_total(text: &str) {
    if let Ok(m) = parse_no_panic(text) {
        m.check().unwrap_or_else(|e| {
            panic!("parse_mtx accepted a structurally-invalid matrix ({e}):\n{text}")
        });
        assert!(m.nrows <= MAX_DIM && m.ncols <= MAX_DIM, "dims over bound: {text}");
        assert!(m.nnz() <= 2 * MAX_NNZ, "nnz over bound (post-mirror): {text}");
    }
}

// ---------------------------------------------------------------------
// Valid-file generator
// ---------------------------------------------------------------------

/// A random *valid* coordinate-format file plus its expected stored-nnz
/// count (mirror entries included) — the baseline the mutations corrupt.
fn gen_valid(g: &mut Gen) -> (String, usize) {
    let symmetric = g.bool(0.4);
    let field = *g.pick(&["real", "integer", "pattern"]);
    let n = g.size(24);
    let (nrows, ncols) = if symmetric { (n, n) } else { (n, g.size(24)) };

    // Distinct coordinates; symmetric files store only r >= c.
    let mut coords: Vec<(usize, usize)> = Vec::new();
    for r in 0..nrows {
        for c in 0..ncols {
            if !symmetric || r >= c {
                coords.push((r, c));
            }
        }
    }
    g.shuffle(&mut coords);
    let nnz = g.size(coords.len());
    coords.truncate(nnz);

    let symmetry = if symmetric { "symmetric" } else { "general" };
    let mut text = format!("%%MatrixMarket matrix coordinate {field} {symmetry}\n");
    if g.bool(0.5) {
        text.push_str("% generated fixture\n");
    }
    text.push_str(&format!("{nrows} {ncols} {nnz}\n"));
    let mut stored = 0usize;
    for &(r, c) in &coords {
        // 1-based indices; pattern files carry no value token. Values
        // avoid exact zero so stored-nnz is predictable.
        match field {
            "pattern" => text.push_str(&format!("{} {}\n", r + 1, c + 1)),
            "integer" => text.push_str(&format!("{} {} {}\n", r + 1, c + 1, g.usize_in(1, 9))),
            _ => text.push_str(&format!("{} {} {:.4}\n", r + 1, c + 1, g.f32() * 1.9 + 0.05)),
        }
        stored += if symmetric && r != c { 2 } else { 1 };
    }
    (text, stored)
}

// ---------------------------------------------------------------------
// Properties
// ---------------------------------------------------------------------

#[test]
fn prop_valid_files_parse_to_checked_matrices() {
    prop::run("valid files parse", 200, |g| {
        let (text, stored) = gen_valid(g);
        let m = parse_no_panic(&text)
            .unwrap_or_else(|e| panic!("valid file rejected ({e}):\n{text}"));
        m.check().expect("loader output passes Csc::check");
        assert_eq!(m.nnz(), stored, "stored nnz (mirror included):\n{text}");
    });
}

#[test]
fn prop_comment_blank_and_crlf_noise_is_transparent() {
    // Comment lines, blank lines, and CRLF endings may appear anywhere
    // after the banner without changing the parse.
    prop::run("comment/CRLF noise", 150, |g| {
        let (text, _) = gen_valid(g);
        let mut noisy = String::new();
        for (i, line) in text.lines().enumerate() {
            noisy.push_str(line);
            noisy.push_str(if g.bool(0.5) { "\r\n" } else { "\n" });
            if i > 0 && g.bool(0.3) {
                noisy.push_str(if g.bool(0.5) { "% noise comment\r\n" } else { "\n" });
            }
        }
        let a = parse_no_panic(&text).expect("baseline valid");
        let b = parse_no_panic(&noisy)
            .unwrap_or_else(|e| panic!("noise changed the verdict ({e}):\n{noisy}"));
        assert_eq!(a, b, "noise changed the matrix:\n{noisy}");
    });
}

#[test]
fn prop_truncation_never_panics() {
    // Every prefix of a valid file — cut mid-banner, mid-header,
    // mid-entry, mid-token — is a typed error or (rarely) still valid.
    prop::run("truncation", 200, |g| {
        let (text, _) = gen_valid(g);
        let cut = g.usize_in(0, text.len() + 1);
        // Cut on a char boundary (the generator is ASCII, but stay safe).
        let cut = (0..=cut).rev().find(|&i| text.is_char_boundary(i)).unwrap_or(0);
        assert_total(&text[..cut]);
    });
}

#[test]
fn prop_token_mutations_never_panic() {
    // Replace one whitespace-separated token anywhere in the file with a
    // hostile literal: overflow sizes, 0/negative indices, non-numbers,
    // non-finite values, huge exponents.
    const HOSTILE: [&str; 12] = [
        "0",
        "-1",
        "18446744073709551616",          // > u64::MAX
        "99999999999999999999999999999", // way past usize
        "1e999",                         // f64 overflow -> inf
        "-1e999",
        "nan",
        "inf",
        "nope",
        "1.0.0",
        "0x10",
        "",
    ];
    prop::run("token mutation", 300, |g| {
        let (text, _) = gen_valid(g);
        let mut tokens: Vec<String> = Vec::new();
        for line in text.lines() {
            for tok in line.split_whitespace() {
                tokens.push(tok.to_string());
            }
        }
        // Rebuild the file with one token swapped for a hostile one;
        // line structure is preserved so the mutation lands in-place.
        let victim = g.usize_in(0, tokens.len());
        let hostile = *g.pick(&HOSTILE);
        let mut i = 0usize;
        let mut mutated = String::new();
        for line in text.lines() {
            let mut first = true;
            for tok in line.split_whitespace() {
                if !first {
                    mutated.push(' ');
                }
                first = false;
                mutated.push_str(if i == victim { hostile } else { tok });
                i += 1;
            }
            mutated.push('\n');
        }
        assert_total(&mutated);
    });
}

#[test]
fn prop_line_shuffles_dups_and_deletions_never_panic() {
    // Structural damage: drop a line, duplicate a line (duplicate
    // coordinates or a count mismatch), or shuffle the data lines
    // (out-of-triangle entries for symmetric files).
    prop::run("line damage", 300, |g| {
        let (text, _) = gen_valid(g);
        let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
        match g.usize_in(0, 3) {
            0 => {
                let i = g.usize_in(0, lines.len());
                lines.remove(i);
            }
            1 => {
                let i = g.usize_in(0, lines.len());
                let dup = lines[i].clone();
                lines.insert(i, dup);
            }
            _ => {
                // Keep the banner in place; shuffle everything below it
                // (the size header may land mid-data).
                g.shuffle(&mut lines[1..]);
            }
        }
        let mutated = lines.join("\n");
        assert_total(&mutated);
    });
}

#[test]
fn prop_random_bytes_never_panic() {
    // No structure at all: printable-ish noise, sometimes starting with
    // a real banner so the parser gets deep before the damage hits.
    const BANNERS: [&str; 3] = [
        "",
        "%%MatrixMarket matrix coordinate real general\n",
        "%%MatrixMarket matrix array real symmetric\n",
    ];
    prop::run("random bytes", 300, |g| {
        let mut text = g.pick(&BANNERS).to_string();
        let len = g.size(512);
        const ALPHABET: &[u8] = b"0123456789 .-+eE%\n\r\tMatrixmarket";
        for _ in 0..len {
            text.push(ALPHABET[g.usize_in(0, ALPHABET.len())] as char);
        }
        assert_total(&text);
    });
}

#[test]
fn prop_registry_is_content_addressed_for_generated_files() {
    prop::run("registry content-addressing", 50, |g| {
        let (text, _) = gen_valid(g);
        let label_a = format!("prop/{}.mtx", g.ident(12));
        let label_b = format!("prop/renamed/{}.mtx", g.ident(12));
        let a = register_text(&label_a, &text).expect("valid file registers");
        let b = register_text(&label_b, &text).expect("re-registration is a no-op");
        assert_eq!(a, b, "identical bytes must resolve to one token");
    });
}

// ---------------------------------------------------------------------
// Deterministic hostile cases (the named edges the issue calls out)
// ---------------------------------------------------------------------

#[test]
fn hostile_headers_are_typed_errors_not_allocations() {
    // Overflow-shaped headers must be rejected *before* any data-sized
    // allocation: a fabricated nnz (or a dense dim pair) past the sanity
    // bounds fails fast even though the file carries no data at all.
    for text in [
        // truncated header: banner only, then EOF
        "%%MatrixMarket matrix coordinate real general\n",
        // truncated header: one token of three
        "%%MatrixMarket matrix coordinate real general\n7\n",
        // nnz over the sanity bound
        &format!("%%MatrixMarket matrix coordinate real general\n1000 1000 {}\n", MAX_NNZ + 1),
        // nnz > cells
        "%%MatrixMarket matrix coordinate real general\n2 2 5\n1 1 1.0\n",
        // dims over the sanity bound
        &format!("%%MatrixMarket matrix coordinate real general\n{} 2 1\n1 1 1.0\n", MAX_DIM + 1),
        // dense cell count overflows the bound without overflowing usize
        "%%MatrixMarket matrix array real general\n1048576 1048576\n",
    ] {
        let e = parse_no_panic(text).unwrap_err();
        assert!(
            matches!(e, MtxError::Header { .. } | MtxError::Entry { .. } | MtxError::Count { .. }),
            "{text:?} -> {e}"
        );
    }
}

#[test]
fn out_of_range_and_duplicate_coordinates_are_entry_errors() {
    for (text, want_line) in [
        // 0-based index smuggled into a 1-based format
        ("%%MatrixMarket matrix coordinate real general\n3 3 1\n0 1 1.0\n", 3),
        // row past nrows
        ("%%MatrixMarket matrix coordinate real general\n3 3 1\n4 1 1.0\n", 3),
        // column past ncols
        ("%%MatrixMarket matrix coordinate real general\n3 3 1\n1 4 1.0\n", 3),
        // duplicate coordinate
        ("%%MatrixMarket matrix coordinate real general\n3 3 2\n2 2 1.0\n2 2 5.0\n", 4),
        // symmetric mirror collides with an explicit transpose entry
        ("%%MatrixMarket matrix coordinate real symmetric\n3 3 2\n2 1 1.0\n2 1 5.0\n", 4),
    ] {
        match parse_no_panic(text).unwrap_err() {
            MtxError::Entry { line, .. } => assert_eq!(line, want_line, "{text:?}"),
            other => panic!("{text:?} -> expected Entry error, got {other}"),
        }
    }
}

#[test]
fn comment_and_crlf_edges_parse() {
    // Comments between data lines, a comment as the last line, CRLF
    // everywhere, and indented entries are all fine.
    let text = "%%MatrixMarket matrix coordinate real general\r\n\
                % leading comment\r\n\
                3 3 2\r\n\
                % mid-data comment\r\n\
                \x20\x201 1 1.5\r\n\
                3\t2\t2.5\r\n\
                % trailing comment\r\n";
    let m = parse_no_panic(text).expect("CRLF + comments + tabs parse");
    assert_eq!((m.nrows, m.ncols, m.nnz()), (3, 3, 2));
}
