//! Integration tests for the simulation-result tier (`service::results`):
//! the cold-vs-replayed determinism regression, warm services replaying
//! every result (`sims == 0`), the read-only result seed, the
//! `--no-result-cache` escape hatch, verify-job bypass, the `.dsr`
//! fault-injection matrix (corrupt entries fall through to a fresh
//! simulation and are rewritten), and the cross-process single-runner
//! lock (two services racing a missing key simulate exactly once).

use dare::coordinator::{BenchPoint, RunSpec};
use dare::kernels::KernelKind;
use dare::service::results::{decode_result, encode_result};
use dare::service::{disk, DiskConfig, DiskStore, ResultKey, Service, ServiceConfig};
use dare::sim::Variant;
use dare::sparse::DatasetKind;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::SystemTime;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dare-e2e-results-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn tiny(kernel: KernelKind, dataset: DatasetKind, variant: Variant) -> RunSpec {
    RunSpec::new(BenchPoint::new(kernel, dataset, 1, 0.04), variant)
}

fn result_key(spec: &RunSpec) -> ResultKey {
    ResultKey::new(&spec.workload_key(), &spec.config())
}

fn dsr_path(dir: &Path, spec: &RunSpec) -> PathBuf {
    dir.join(format!("{}.dsr", result_key(spec).file_stem()))
}

fn dsr_files(dir: &Path) -> Vec<PathBuf> {
    let mut v: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|s| s.to_str()) == Some("dsr"))
        .collect();
    v.sort();
    v
}

/// `(name, content, mtime)` of every file in `dir` — the seed-tier
/// "nothing here may ever change" witness.
fn dir_snapshot(dir: &Path) -> Vec<(String, Vec<u8>, SystemTime)> {
    let mut v: Vec<(String, Vec<u8>, SystemTime)> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .map(|e| {
            let name = e.file_name().to_string_lossy().into_owned();
            let content = std::fs::read(e.path()).unwrap();
            let mtime = e.metadata().unwrap().modified().unwrap();
            (name, content, mtime)
        })
        .collect();
    v.sort();
    v
}

fn service_at(dir: &Path, workers: usize) -> Service {
    Service::start(ServiceConfig {
        workers,
        disk: Some(DiskConfig::new(dir)),
        ..ServiceConfig::default()
    })
}

/// The acceptance-criteria determinism regression: the stats a cold
/// simulation produces and the stats a warm service replays from the
/// `.dsr` entry are bit-identical — asserted by comparing the canonical
/// entry encodings, which cover every counter (and the one f64 by bit
/// pattern), not just a couple of headline fields.
#[test]
fn cold_and_replayed_results_are_bit_identical() {
    let dir = tmp_dir("bit-identical");
    let specs = vec![
        tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::Baseline),
        tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::DareFre),
        tiny(KernelKind::SpMM, DatasetKind::PubMed, Variant::DareFull),
    ];
    let cold = service_at(&dir, 2);
    let cold_results = cold.run_batch(&specs);
    assert_eq!(cold.metrics().sims, specs.len() as u64, "every cold job simulates");
    drop(cold);

    let warm = service_at(&dir, 2);
    let warm_results = warm.run_batch(&specs);
    let m = warm.metrics();
    assert_eq!(m.sims, 0, "a warm service replays, never simulates");
    for (spec, (a, b)) in specs.iter().zip(cold_results.iter().zip(&warm_results)) {
        let rk = result_key(spec);
        assert_eq!(a.name, b.name);
        assert_eq!(
            encode_result(&rk, &a.stats),
            encode_result(&rk, &b.stats),
            "replayed stats must be bit-identical for {}",
            a.name
        );
        // The derived energy is a pure function of the stats.
        assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits(), "{}", a.name);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The warm-sweep acceptance metric end-to-end: second service over the
/// same cache dir reports builds == 0 **and** sims == 0, with every job
/// a result hit.
#[test]
fn warm_service_replays_every_result_without_building() {
    let dir = tmp_dir("warm");
    let specs: Vec<RunSpec> = [Variant::Baseline, Variant::Nvr, Variant::DareFre]
        .into_iter()
        .flat_map(|v| {
            [DatasetKind::PubMed, DatasetKind::Gpt2Attention]
                .into_iter()
                .map(move |d| RunSpec::new(BenchPoint::new(KernelKind::Sddmm, d, 1, 0.04), v))
        })
        .collect();
    let cold = service_at(&dir, 2);
    let _ = cold.run_batch(&specs);
    drop(cold);
    assert_eq!(dsr_files(&dir).len(), specs.len(), "one .dsr entry per (workload, config)");

    let warm = service_at(&dir, 2);
    let _ = warm.run_batch(&specs);
    let m = warm.metrics();
    let c = m.cache;
    assert_eq!(m.sims, 0, "warm run simulates nothing");
    assert_eq!(c.builds(), 0, "warm run compiles nothing");
    assert_eq!(c.result_hits, specs.len() as u64, "every job replayed from the .dsr tier");
    assert_eq!(c.result_misses, 0);
    assert!(
        c.result_hit_rate() >= 0.9,
        "warm result hit rate {} below the CI bar",
        c.result_hit_rate()
    );
    // Replays skip the workload tiers entirely.
    assert_eq!(c.lookups(), 0, "no workload fetch behind a result replay");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Result entries ride the read-only seed tier: a fresh writable dir
/// over a previous run's cache replays every result from the seed,
/// promotes each into the writable tier, and never writes the seed.
#[test]
fn seeded_service_simulates_nothing_and_never_writes_the_seed() {
    let seed = tmp_dir("seed-src");
    let writable = tmp_dir("seed-writable");
    let specs = vec![
        tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::Baseline),
        tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::DareFull),
    ];
    let cold = service_at(&seed, 2);
    let cold_results = cold.run_batch(&specs);
    drop(cold);
    let before = dir_snapshot(&seed);

    let seeded = Service::start(ServiceConfig {
        workers: 2,
        disk: Some(DiskConfig::new(&writable).with_seed(&seed)),
        ..ServiceConfig::default()
    });
    let seeded_results = seeded.run_batch(&specs);
    let m = seeded.metrics();
    assert_eq!(m.sims, 0, "a seeded run simulates nothing");
    assert_eq!(m.cache.result_seed_hits, specs.len() as u64);
    assert_eq!(m.cache.result_misses, 0);
    for (a, b) in cold_results.iter().zip(&seeded_results) {
        assert_eq!(a.stats.cycles, b.stats.cycles, "{}", a.name);
    }
    // Promoted: a third service over the writable dir alone replays
    // without the seed.
    assert_eq!(dsr_files(&writable).len(), specs.len(), "seed hits promoted to writable tier");
    drop(seeded);
    let third = service_at(&writable, 2);
    let _ = third.run_batch(&specs);
    let m = third.metrics();
    assert_eq!((m.sims, m.cache.result_seed_hits), (0, 0));
    assert_eq!(m.cache.result_hits, specs.len() as u64);
    // Byte-for-byte and mtime-for-mtime, the seed is exactly what it was.
    assert_eq!(dir_snapshot(&seed), before, "the seed must never be written or touched");
    let _ = std::fs::remove_dir_all(&seed);
    let _ = std::fs::remove_dir_all(&writable);
}

/// `--no-result-cache`: the escape hatch re-simulates every job (and
/// counts no result probes), while workload builds still cache.
#[test]
fn disabled_result_tier_re_simulates_every_warm_job() {
    let dir = tmp_dir("no-result-cache");
    let specs = vec![
        tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::Baseline),
        tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::DareFre),
    ];
    let cold = service_at(&dir, 2);
    let _ = cold.run_batch(&specs);
    drop(cold);

    let warm = Service::start(ServiceConfig {
        workers: 2,
        disk: Some(DiskConfig::new(&dir)),
        result_cache: false,
        ..ServiceConfig::default()
    });
    let _ = warm.run_batch(&specs);
    let m = warm.metrics();
    assert_eq!(m.sims, specs.len() as u64, "every job re-simulates");
    let c = m.cache;
    assert_eq!((c.result_hits, c.result_misses, c.result_seed_hits), (0, 0, 0));
    // The workload tier still serves: both specs share one strided
    // build, loaded from disk, zero compiles.
    assert_eq!(c.builds(), 0, "workload builds still cache");
    assert_eq!(c.disk_hits, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Verification jobs rerun the functional model against the memory
/// image — `SimStats` doesn't capture that, so they bypass the result
/// tier in both directions: never served by it, never stored into it.
#[test]
fn verify_jobs_bypass_the_result_tier() {
    let dir = tmp_dir("verify-bypass");
    let mut spec = tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::DareFre);
    spec.verify = true;
    let service = service_at(&dir, 1);
    let results = service.run_batch(&[spec.clone(), spec.clone()]);
    assert!(results.iter().all(|r| r.verify_err.is_some()), "verify jobs verified");
    let m = service.metrics();
    assert_eq!(m.sims, 2, "verify jobs always simulate");
    let c = m.cache;
    assert_eq!((c.result_hits, c.result_misses, c.result_seed_hits), (0, 0, 0));
    assert!(dsr_files(&dir).is_empty(), "verify jobs never write .dsr entries");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `.dsr` fault-injection matrix at the decode boundary: every
/// bit-flip and truncation of a real entry must fail closed (an `Err`,
/// never a panic, never silently wrong stats).
#[test]
fn dsr_corruption_is_always_detected() {
    let dir = tmp_dir("dsr-decode-matrix");
    let spec = tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::DareFre);
    let service = service_at(&dir, 1);
    let _ = service.run_batch(std::slice::from_ref(&spec));
    drop(service);
    let rk = result_key(&spec);
    let pristine = std::fs::read(dsr_path(&dir, &spec)).unwrap();
    decode_result(&rk, &pristine).expect("pristine entry decodes");
    // Bit-flip sweep across the whole entry — magic, version, checksum,
    // length, and compressed payload alike. Offsets 6–7 are the header's
    // reserved (ignored) field, the only bytes a flip may not trip.
    for i in (0..pristine.len()).filter(|i| !(6..8).contains(i)) {
        let mut bad = pristine.clone();
        bad[i] ^= 0x04;
        assert!(decode_result(&rk, &bad).is_err(), "flip at byte {i} must not decode");
    }
    // Truncation sweep.
    for n in 0..pristine.len() {
        assert!(decode_result(&rk, &pristine[..n]).is_err(), "prefix {n} must not decode");
    }
    // Hostile declared lengths are rejected before any allocation.
    let huge = disk::frame(disk::CODEC_VERSION, 0, u64::MAX, &[0u8; 8]);
    assert!(decode_result(&rk, &huge).unwrap_err().contains("sanity"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupt writable `.dsr` entries fall through to a fresh simulation
/// and are rewritten — the entry heals byte-for-byte (the codec is
/// deterministic), and the job still succeeds with correct stats.
#[test]
fn corrupt_result_entries_fall_through_to_simulation_and_rewrite() {
    let dir = tmp_dir("dsr-heal");
    let spec = tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::DareFre);
    let cold = service_at(&dir, 1);
    let baseline = cold.run_batch(std::slice::from_ref(&spec));
    drop(cold);
    let path = dsr_path(&dir, &spec);
    let pristine = std::fs::read(&path).unwrap();

    type Mutate = fn(&[u8]) -> Vec<u8>;
    let cases: [(&str, Mutate); 4] = [
        ("truncated", |b| b[..b.len() - 5].to_vec()),
        ("bit-flip", |b| {
            let mut v = b.to_vec();
            let mid = v.len() / 2;
            v[mid] ^= 0x20;
            v
        }),
        ("future-version", |b| {
            let mut v = b.to_vec();
            let bumped = (disk::CODEC_VERSION + 1).to_le_bytes();
            v[4] = bumped[0];
            v[5] = bumped[1];
            v
        }),
        ("garbage", |b| vec![0xA5; b.len().min(48)]),
    ];
    for (tag, mutate) in cases {
        std::fs::write(&path, mutate(&pristine)).unwrap();
        let service = service_at(&dir, 1);
        let results = service.run_batch(std::slice::from_ref(&spec));
        let m = service.metrics();
        assert_eq!(m.sims, 1, "{tag}: corrupt entry must re-simulate, not replay");
        assert_eq!(m.cache.result_hits, 0, "{tag}");
        assert_eq!(results[0].stats.cycles, baseline[0].stats.cycles, "{tag}");
        let healed = std::fs::read(&path).unwrap_or_else(|e| panic!("{tag}: rewritten: {e}"));
        assert_eq!(healed, pristine, "{tag}: deterministic re-simulation re-persists identically");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two services (≈ two processes) over one cache dir racing the same
/// missing result key: the single-runner flock serializes them, so the
/// simulation runs exactly once and the loser replays the winner's
/// entry.
#[cfg(unix)]
#[test]
fn concurrent_services_simulate_a_result_exactly_once() {
    let dir = tmp_dir("two-runners");
    let spec = tiny(KernelKind::Sddmm, DatasetKind::PubMed, Variant::DareFre);
    let services: Vec<Arc<Service>> = (0..2).map(|_| Arc::new(service_at(&dir, 1))).collect();
    let barrier = Arc::new(std::sync::Barrier::new(services.len()));
    let handles: Vec<_> = services
        .iter()
        .map(|service| {
            let service = service.clone();
            let spec = spec.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                service.run_batch(std::slice::from_ref(&spec))[0].stats.cycles
            })
        })
        .collect();
    let cycles: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(cycles[0], cycles[1], "both runners observe identical stats");
    let total_sims: u64 = services.iter().map(|s| s.metrics().sims).sum();
    let total_replays: u64 = services.iter().map(|s| s.metrics().cache.result_hits).sum();
    assert_eq!(total_sims, 1, "the run lock admits exactly one simulation");
    assert_eq!(total_replays, 1, "the other runner replays the winner's entry");
    assert_eq!(dsr_files(&dir).len(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A direct store-level round trip through `DiskStore`, plus the
/// per-tier stats split: result entries are visible under
/// `stats().results`, not `stats().workloads`.
#[test]
fn store_level_result_round_trip_and_stats_split() {
    let dir = tmp_dir("store-level");
    let spec = tiny(KernelKind::SpMM, DatasetKind::PubMed, Variant::Baseline);
    let rk = result_key(&spec);
    let store = DiskStore::open(DiskConfig::new(&dir)).unwrap();
    assert!(store.load_result(&rk).is_none(), "cold store misses");
    let mut stats = dare::sim::SimStats::default();
    stats.cycles = 424242;
    stats.dram.busy_cycles = 3.5;
    let stored = store.store_result(&rk, &stats).unwrap();
    assert!(stored.stored_bytes > 0);
    let loaded = store.load_result(&rk).expect("stored entry loads");
    assert!(!loaded.from_seed);
    assert_eq!(loaded.stats.cycles, 424242);
    assert_eq!(loaded.stats.dram.busy_cycles.to_bits(), 3.5f64.to_bits());
    let s = store.stats();
    assert_eq!((s.workloads.entries, s.results.entries), (0, 1), "tier split");
    assert_eq!(s.results.versions, vec![(disk::CODEC_VERSION, 1)]);
    assert_eq!(store.clear().unwrap(), 1, "clear covers .dsr entries");
    let _ = std::fs::remove_dir_all(&dir);
}
