//! Property-based tests on coordinator/simulator invariants, using the
//! in-repo seeded runner (`dare::util::prop`) — replay any failure with
//! `DARE_PROP_SEED=0x... cargo test <name>`.

use dare::isa::{asm, encode::ArchInstr, MInstr, MReg, MatShape, Program, ProgramBuilder};
use dare::kernels::{compile_sddmm, compile_spmm};
use dare::mem::{Llc, LlcConfig, MemRequest};
use dare::sim::{Mpu, NativeMma, SimConfig, MemImage, Variant};
use dare::sparse::{blockify_structurize, Csc, Dense, Triplet};
use dare::util::prop::{run, Gen};

fn random_csc(g: &mut Gen, max_dim: usize, max_density: f64) -> Csc {
    let nrows = g.usize_in(1, max_dim);
    let ncols = g.usize_in(1, max_dim);
    let density = g.f64() * max_density;
    let mut ts = Vec::new();
    for r in 0..nrows {
        for c in 0..ncols {
            if g.bool(density) {
                ts.push(Triplet {
                    row: r as u32,
                    col: c as u32,
                    val: g.f32() * 2.0 - 1.0,
                });
            }
        }
    }
    Csc::from_triplets(nrows, ncols, ts)
}

#[test]
fn prop_csc_roundtrips_and_invariants() {
    run("csc_roundtrip", 60, |g| {
        let m = random_csc(g, 24, 0.4);
        m.check().expect("structural invariants");
        let d = m.to_dense();
        // dense → csc drops explicit zeros, so compare patterns modulo 0
        let m2 = Csc::from_dense(&d);
        assert_eq!(m2.to_dense(), d);
        let csr = m.to_csr();
        assert_eq!(csr.to_dense(), d, "csr view agrees");
        assert_eq!(csr.to_csc().to_dense(), d, "csc→csr→csc stable");
    });
}

#[test]
fn prop_blockify_structurize_keeps_budget_and_block_shape() {
    run("blockify_budget", 40, |g| {
        let m = random_csc(g, 32, 0.2);
        if m.nnz() == 0 {
            return;
        }
        let block = *g.pick(&[2usize, 4, 8]);
        let b = blockify_structurize(&m, block, g.u64());
        b.check().unwrap();
        // budget: kept slots overshoot the original nnz by < one block
        assert!(b.nnz() >= m.nnz().min(1));
        assert!(
            b.nnz() < m.nnz() + block * block,
            "nnz {} vs budget {} (+{})",
            b.nnz(),
            m.nnz(),
            block * block
        );
        // every stored entry lies in a fully-dense (or edge-clipped) block
        let dense = b.to_dense();
        for c in 0..b.ncols {
            for &r in b.col_rows(c) {
                let r0 = (r as usize / block) * block;
                let c0 = (c / block) * block;
                for rr in r0..(r0 + block).min(b.nrows) {
                    for cc in c0..(c0 + block).min(b.ncols) {
                        assert!(
                            dense.at(rr, cc) != 0.0,
                            "block ({r0},{c0}) not dense at ({rr},{cc})"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn prop_isa_encode_decode_roundtrip() {
    run("isa_roundtrip", 200, |g| {
        let mr = |g: &mut Gen| MReg(g.usize_in(0, 8) as u8);
        let gpr = |g: &mut Gen| g.usize_in(0, 32) as u8;
        let i = match g.usize_in(0, 6) {
            0 => ArchInstr::Mcfg { rs1: gpr(g), rs2: gpr(g) },
            1 => ArchInstr::Mld { md: mr(g), rs1: gpr(g), rs2: gpr(g) },
            2 => ArchInstr::Mst { ms3: mr(g), rs1: gpr(g), rs2: gpr(g) },
            3 => ArchInstr::Mma { md: mr(g), ms1: mr(g), ms2: mr(g) },
            4 => ArchInstr::Mgather { md: mr(g), ms1: mr(g) },
            _ => ArchInstr::Mscatter { ms2: mr(g), ms1: mr(g) },
        };
        assert_eq!(ArchInstr::decode(i.encode()), Ok(i));
    });
}

#[test]
fn prop_asm_roundtrip_random_programs() {
    run("asm_roundtrip", 60, |g| {
        let mut b = ProgramBuilder::new("rand");
        for _ in 0..g.size(40) {
            let md = MReg(g.usize_in(0, 8) as u8);
            let ms = MReg(g.usize_in(0, 8) as u8);
            match g.usize_in(0, 5) {
                0 => b.mld(md, g.u64() & 0xFFFF_FFFF, g.usize_in(4, 512) as u64),
                1 => b.mst(md, g.u64() & 0xFFFF_FFFF, g.usize_in(4, 512) as u64),
                2 => b.mma(md, ms, MReg(g.usize_in(0, 8) as u8), None),
                3 => b.mgather(md, ms),
                _ => b.mscatter(md, ms),
            }
        }
        let prog = b.build();
        let text = asm::disassemble(&prog.instrs);
        let parsed = asm::assemble(&text).expect("disassembly must re-assemble");
        assert_eq!(parsed, prog.instrs);
    });
}

#[test]
fn prop_llc_conservation_and_inclusion() {
    run("llc_conservation", 30, |g| {
        let mut llc = Llc::new(LlcConfig {
            size_bytes: 16 * 1024,
            ways: 4,
            banks: 4,
            hit_latency: 5,
            oracle: false,
            dram: Default::default(),
        });
        let n_req = g.size(200);
        let mut issued = 0u64;
        let mut completed = 0u64;
        let mut now = 0u64;
        let mut id = 0u64;
        for _ in 0..n_req {
            now += 1 + g.usize_in(0, 3) as u64;
            completed += llc.tick(now).len() as u64;
            let req = MemRequest {
                id,
                addr: (g.usize_in(0, 64) * 64) as u64,
                is_write: g.bool(0.3),
                is_prefetch: g.bool(0.3),
            };
            if llc.access(req, now).is_ok() {
                issued += 1;
                id += 1;
            }
        }
        // drain
        for _ in 0..100_000 {
            now += 1;
            completed += llc.tick(now).len() as u64;
            if llc.inflight() == 0 {
                break;
            }
        }
        assert_eq!(completed, issued, "every accepted request completes exactly once");
        let s = llc.stats;
        assert_eq!(
            s.demand_hits + s.demand_misses,
            s.demand_reads + s.demand_writes,
            "demand accesses partition into hits and misses"
        );
        assert!(s.prefetch_redundant + s.prefetch_useful_fills <= s.prefetches + s.mshr_merges);
    });
}

#[test]
fn prop_simulator_functional_equivalence_across_variants() {
    // The core end-to-end property: whatever the variant and timing
    // path, the simulated MPU computes exactly the reference result.
    run("variant_equivalence", 12, |g| {
        let m = random_csc(g, 28, 0.25);
        if m.nnz() == 0 {
            return;
        }
        let f = *g.pick(&[16usize, 32, 64]);
        let gsa = g.bool(0.5);
        let w = if g.bool(0.5) {
            compile_spmm(&m, f, gsa, g.u64())
        } else {
            compile_sddmm(&m, f, gsa, g.u64())
        };
        let variants: &[Variant] = if gsa {
            &[Variant::DareGsa, Variant::DareFull]
        } else {
            &[Variant::Baseline, Variant::Nvr, Variant::DareFre]
        };
        for &v in variants {
            let mut cfg = SimConfig::for_variant(v);
            cfg.max_cycles = 20_000_000;
            let mut mpu = Mpu::new(cfg, w.mem.clone(), Box::new(NativeMma));
            let stats = mpu.run(&w.program);
            assert_eq!(stats.instrs_retired as usize, w.program.instrs.len());
            w.verify(&mpu.mem, 1e-3)
                .unwrap_or_else(|e| panic!("{v:?} on {}: {e}", w.program.name));
        }
    });
}

#[test]
fn prop_riq_vmr_never_leak() {
    run("no_leaks", 10, |g| {
        let m = random_csc(g, 24, 0.3);
        if m.nnz() == 0 {
            return;
        }
        let w = compile_spmm(&m, 32, true, g.u64());
        let mut cfg = SimConfig::for_variant(Variant::DareFull);
        cfg.vmr_entries = g.usize_in(2, 16);
        cfg.riq_entries = g.usize_in(4, 32);
        cfg.max_cycles = 20_000_000;
        let mut mpu = Mpu::new(cfg, w.mem.clone(), Box::new(NativeMma));
        let stats = mpu.run(&w.program);
        assert_eq!(stats.vmr.allocs, stats.vmr.releases, "VMR entries all released");
        assert!(stats.riq.peak_occupancy <= mpu.config().riq_entries);
    });
}

#[test]
fn prop_dense_matmul_reference_identities() {
    run("matmul_identities", 40, |g| {
        let m = g.usize_in(1, 12);
        let k = g.usize_in(1, 12);
        let n = g.usize_in(1, 12);
        let a = Dense { rows: m, cols: k, data: g.vec_f32(m * k) };
        let b = Dense { rows: n, cols: k, data: g.vec_f32(n * k) };
        let via_bt = a.matmul_bt(&b);
        let via_t = a.matmul(&b.transpose());
        assert!(via_bt.max_abs_diff(&via_t) < 1e-4);
        // (A·Bᵀ)ᵀ = B·Aᵀ
        let lhs = via_bt.transpose();
        let rhs = b.matmul_bt(&a);
        assert!(lhs.max_abs_diff(&rhs) < 1e-4);
    });
}

#[test]
fn prop_program_builder_shapes_always_valid() {
    run("builder_shapes", 80, |g| {
        let mut b = ProgramBuilder::new("t");
        for _ in 0..g.size(20) {
            let m = g.usize_in(1, 17) as u16;
            let k = (g.usize_in(1, 17) as u16) * 4;
            let n = g.usize_in(1, 17) as u16;
            let shape = MatShape { m, k, n };
            if shape.validate().is_ok() {
                b.cfg_shape(shape);
                b.mma(MReg(0), MReg(1), MReg(2), Some(0));
            }
        }
        let p: Program = b.build();
        assert!(p.useful_macs <= p.issued_macs);
        // every emitted program re-assembles
        let text = asm::disassemble(&p.instrs);
        assert_eq!(asm::assemble(&text).unwrap(), p.instrs);
    });
}

#[test]
fn prop_rfu_classifier_separates_any_bimodal_regime() {
    use dare::sim::config::RfuConfig;
    use dare::sim::rfu::Rfu;
    run("rfu_bimodal", 50, |g| {
        let hit = 10 + g.usize_in(0, 100) as u64;
        // miss mode well past the margin (≥ 6 bins away) with jitter
        let gap = 64 + g.usize_in(0, 300) as u64;
        let miss = hit + gap;
        let mut rfu = Rfu::new(RfuConfig::default(), hit);
        for i in 0..32u64 {
            rfu.observe(hit + i % 4);
            rfu.observe(miss + i % 6);
        }
        if rfu.stats.threshold_updates > 0 {
            // when the classifier commits to a threshold it must separate
            // the two modes
            assert!(
                !rfu.classify_miss(hit),
                "hit {hit} misclassified (threshold {})",
                rfu.threshold()
            );
            assert!(
                rfu.classify_miss(miss + 5),
                "miss {miss} misclassified (threshold {})",
                rfu.threshold()
            );
        }
    });
}

#[test]
fn prop_energy_monotone_in_event_counts() {
    use dare::energy::{energy_of, EnergyModel};
    use dare::sim::SimStats;
    run("energy_monotone", 60, |g| {
        let model = EnergyModel::default();
        let mut a = SimStats::default();
        a.cycles = 1 + g.usize_in(0, 100_000) as u64;
        a.useful_macs = 1 + g.usize_in(0, 1_000_000) as u64;
        a.demand_uops = g.usize_in(0, 100_000) as u64;
        a.llc.slots_used = a.demand_uops + g.usize_in(0, 10_000) as u64;
        a.dram.reads = g.usize_in(0, 50_000) as u64;
        a.systolic.active_pe_cycles = g.usize_in(0, 1_000_000) as u64;
        a.systolic.provisioned_pe_cycles = a.systolic.active_pe_cycles * 2;
        let base = energy_of(&a, &model).total_pj();
        // adding DRAM traffic can only increase energy
        let mut b = a;
        b.dram.reads += 1000;
        assert!(energy_of(&b, &model).total_pj() > base);
        // adding cycles can only increase energy (static)
        let mut c = a;
        c.cycles += 1000;
        assert!(energy_of(&c, &model).total_pj() > base);
    });
}

#[test]
fn prop_gather_program_equals_strided_program_output() {
    // The *same problem* lowered with and without GSA must produce the
    // same reference expectation AND the same simulated memory contents.
    run("gsa_strided_agree", 8, |g| {
        let m = random_csc(g, 20, 0.3);
        if m.nnz() == 0 {
            return;
        }
        let seed = g.u64();
        let strided = compile_sddmm(&m, 32, false, seed);
        let gsa = compile_sddmm(&m, 32, true, seed);
        assert_eq!(strided.checks[0].expect, gsa.checks[0].expect);
        let mut cfg_s = SimConfig::for_variant(Variant::Baseline);
        cfg_s.max_cycles = 20_000_000;
        let mut mpu_s = Mpu::new(cfg_s, strided.mem.clone(), Box::new(NativeMma));
        mpu_s.run(&strided.program);
        let mut cfg_g = SimConfig::for_variant(Variant::DareFull);
        cfg_g.max_cycles = 20_000_000;
        let mut mpu_g = Mpu::new(cfg_g, gsa.mem.clone(), Box::new(NativeMma));
        mpu_g.run(&gsa.program);
        let addr = strided.checks[0].addr;
        let n = strided.checks[0].expect.len();
        let out_s = mpu_s.mem.read_f32_slice(addr, n);
        let out_g = mpu_g.mem.read_f32_slice(gsa.checks[0].addr, n);
        for (i, (a, b)) in out_s.iter().zip(&out_g).enumerate() {
            assert!((a - b).abs() < 1e-4, "output {i}: strided {a} vs gsa {b}");
        }
    });
}

#[test]
fn prop_memimage_rw_roundtrip() {
    run("memimage_roundtrip", 60, |g| {
        let size = g.usize_in(64, 4096);
        let mut mem = MemImage::new(size);
        let n_writes = g.size(50);
        let mut shadow = vec![0u8; size];
        for _ in 0..n_writes {
            let len = g.usize_in(1, 17).min(size);
            let addr = g.usize_in(0, size - len + 1) as u64;
            let data: Vec<u8> = (0..len).map(|_| g.u32() as u8).collect();
            mem.write_bytes(addr, &data);
            shadow[addr as usize..addr as usize + len].copy_from_slice(&data);
        }
        let lo = g.usize_in(0, size) as u64;
        let len = g.usize_in(0, size - lo as usize + 1);
        assert_eq!(mem.read_bytes(lo, len), &shadow[lo as usize..lo as usize + len]);
    });
}
