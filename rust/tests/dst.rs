//! Integration tests for the DST harness itself: a seeded run with
//! every fault class enabled must finish with zero invariant
//! violations, and two runs of the same seed must produce byte-for-byte
//! identical traces and reports (the property every CI failure relies
//! on to reproduce locally).

use dare::dst::{run, ActorKind, DstConfig, FaultSpec};

/// A moderate schedule: long enough to exercise every actor kind and
/// consume disk faults, short enough for a debug-build test run.
fn config(seed: u64) -> DstConfig {
    let mut cfg = DstConfig::new(seed);
    cfg.steps = 60;
    cfg
}

#[test]
fn same_seed_runs_are_byte_identical() {
    let cfg = config(0xDA5E);
    let a = run(&cfg).expect("dst run sets up");
    let b = run(&cfg).expect("dst run sets up");
    assert_eq!(a.violations, Vec::<String>::new(), "first run is clean");
    assert_eq!(b.violations, Vec::<String>::new(), "second run is clean");
    assert_eq!(a.trace, b.trace, "same seed, same trace, line for line");
    assert_eq!(a.trace_digest, b.trace_digest);
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.steps_run, cfg.steps);
    // The schedule actually did something: every enabled actor stepped
    // at least zero times (counts present), and the trace is per-step.
    assert_eq!(a.trace.len() as u64, cfg.steps);
    assert_eq!(a.actor_counts.len(), ActorKind::ALL.len());
    assert_eq!(a.actor_counts.iter().map(|(_, n)| n).sum::<u64>(), cfg.steps);
}

#[test]
fn different_seeds_diverge() {
    let a = run(&config(1)).expect("dst run sets up");
    let b = run(&config(2)).expect("dst run sets up");
    assert!(a.violations.is_empty(), "{:?}", a.violations);
    assert!(b.violations.is_empty(), "{:?}", b.violations);
    assert_ne!(
        a.trace_digest, b.trace_digest,
        "different seeds should explore different schedules"
    );
}

#[test]
fn fault_heavy_run_survives_with_faults_consumed() {
    // All disk-fault classes on, sessions + direct traffic only: every
    // armed crash/torn/full plan flows through a real entry write.
    let mut cfg = config(7);
    cfg.steps = 40;
    cfg.actors = vec![ActorKind::Client, ActorKind::Drain, ActorKind::Direct];
    cfg.faults = FaultSpec::parse("crash-rename,torn-frame,disk-full").unwrap();
    let report = run(&cfg).expect("dst run sets up");
    assert_eq!(report.violations, Vec::<String>::new());
    let armed: u64 = report.fault_counts.iter().map(|(_, n)| n).sum();
    assert!(armed > 0, "a 40-step 35%-fault schedule arms at least one plan");
    assert!(
        report.faults_consumed <= armed,
        "consumed ({}) cannot exceed armed ({armed})",
        report.faults_consumed
    );
}

#[test]
fn fault_free_run_is_all_ok() {
    let mut cfg = config(3);
    cfg.steps = 30;
    cfg.faults = FaultSpec::none();
    // `none` disables drop-conn and corrupt-entry, so those actors are
    // gated out of the pool by the scheduler.
    let report = run(&cfg).expect("dst run sets up");
    assert_eq!(report.violations, Vec::<String>::new());
    assert_eq!(report.faults_consumed, 0);
    assert_eq!(report.final_audit.corrupt(), 0, "no faults, no corruption");
    assert_eq!(report.final_audit.panicked, 0);
}
