//! Cross-module integration tests: kernel compilers → simulator →
//! verification, across variants, kernels, datasets and block sizes —
//! plus the runtime path executing simulated `mma`s through the AOT
//! Pallas artifact.

use dare::coordinator::{run_many, run_one, BenchPoint, RunSpec};
use dare::kernels::KernelKind;
use dare::runtime::artifacts_available;
use dare::sim::Variant;
use dare::sparse::DatasetKind;

const SCALE: f64 = 0.05;

fn spec(kernel: KernelKind, dataset: DatasetKind, block: usize, v: Variant) -> RunSpec {
    let mut s = RunSpec::new(BenchPoint::new(kernel, dataset, block, SCALE), v);
    s.verify = true;
    s
}

#[test]
fn every_variant_verifies_on_every_kernel_and_dataset() {
    let mut specs = Vec::new();
    for kernel in [KernelKind::SpMM, KernelKind::Sddmm] {
        for dataset in DatasetKind::ALL {
            for block in [1usize, 8] {
                for v in Variant::ALL {
                    specs.push(spec(kernel, dataset, block, v));
                }
            }
        }
    }
    // 2 × 4 × 2 × 5 = 80 runs, all functionally verified inside run_one.
    let results = run_many(&specs, 0);
    assert_eq!(results.len(), 80);
    for r in &results {
        assert!(r.stats.cycles > 0, "{} ran", r.name);
        assert!(r.verify_err.unwrap() < 1e-3, "{} verified", r.name);
    }
}

#[test]
fn gemm_verifies_on_all_variants() {
    for v in Variant::ALL {
        let r = run_one(&spec(KernelKind::Gemm, DatasetKind::PubMed, 1, v), false);
        assert!(r.verify_err.unwrap() < 1e-3, "{}", r.name);
    }
}

#[test]
fn dare_full_beats_baseline_on_irregular_workloads() {
    // The headline claim at B=1 (unstructured sparsity).
    for kernel in [KernelKind::SpMM, KernelKind::Sddmm] {
        for dataset in [DatasetKind::PubMed, DatasetKind::OgblCollab] {
            let base = run_one(&spec(kernel, dataset, 1, Variant::Baseline), false);
            let dare = run_one(&spec(kernel, dataset, 1, Variant::DareFull), false);
            assert!(
                dare.stats.cycles < base.stats.cycles,
                "{}: DARE-full {} !< baseline {}",
                base.name,
                dare.stats.cycles,
                base.stats.cycles
            );
        }
    }
}

#[test]
fn dare_never_loses_to_baseline() {
    // DARE = better(FRE, full) must be ≥ 1.0× vs baseline everywhere
    // (the paper's floor is 1.04×).
    for kernel in [KernelKind::SpMM, KernelKind::Sddmm] {
        for block in [1usize, 8] {
            let d = DatasetKind::Gpt2Attention;
            let base = run_one(&spec(kernel, d, block, Variant::Baseline), false);
            let fre = run_one(&spec(kernel, d, block, Variant::DareFre), false);
            let full = run_one(&spec(kernel, d, block, Variant::DareFull), false);
            let dare = fre.stats.cycles.min(full.stats.cycles);
            assert!(
                dare <= base.stats.cycles,
                "{} B={block}: DARE {dare} vs baseline {}",
                kernel.name(),
                base.stats.cycles
            );
        }
    }
}

#[test]
fn variants_compute_identical_results() {
    // All designs must produce bit-comparable outputs for the same
    // problem (timing differences must never leak into values).
    let point = BenchPoint::new(KernelKind::SpMM, DatasetKind::OgbnProteins, 1, SCALE);
    let strided = point.build(false);
    let gsa = point.build(true);
    assert_eq!(strided.checks[0].expect, gsa.checks[0].expect);
}

#[test]
fn xla_and_native_backends_agree_cycle_for_cycle() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let s = spec(KernelKind::Sddmm, DatasetKind::PubMed, 1, Variant::DareFull);
    let native = run_one(&s, false);
    let xla = run_one(&s, true);
    // The functional backend cannot affect timing...
    assert_eq!(native.stats.cycles, xla.stats.cycles, "timing must be backend-invariant");
    // ...and both verify against the same reference.
    assert!(xla.verify_err.unwrap() < 1e-3);
}

#[test]
fn nvr_emulation_has_unbounded_runahead_structures() {
    let s = spec(KernelKind::Sddmm, DatasetKind::OgbnProteins, 1, Variant::Nvr);
    let r = run_one(&s, false);
    // NVR's infinite RIQ must actually be exercised beyond DARE's 32.
    assert!(
        r.stats.riq.peak_occupancy > 32,
        "NVR RIQ peak {} should exceed DARE's 32-entry budget",
        r.stats.riq.peak_occupancy
    );
    assert_eq!(r.stats.riq.dispatch_stalls, 0, "infinite RIQ never stalls dispatch");
}

#[test]
fn oracle_cache_bounds_all_designs() {
    let p = BenchPoint::new(KernelKind::Sddmm, DatasetKind::PubMed, 1, SCALE);
    let mut oracle = RunSpec::new(p, Variant::Baseline);
    oracle.oracle_llc = true;
    let ro = run_one(&oracle, false);
    for v in Variant::ALL {
        if v == Variant::DareGsa || v == Variant::DareFull {
            continue; // different program shape; not directly comparable
        }
        let r = run_one(&RunSpec::new(p, v), false);
        assert!(
            ro.stats.cycles <= r.stats.cycles,
            "oracle ({}) must lower-bound {} ({})",
            ro.stats.cycles,
            v.name(),
            r.stats.cycles
        );
    }
}
