"""spmm_update kernel vs oracle (hypothesis sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.spmm_update import spmm_update
from compile.kernels import ref


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 16),
    f=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_update_matches_ref(m, f, seed):
    ka, kb, kc = jax.random.split(jax.random.PRNGKey(seed), 3)
    c = jax.random.uniform(ka, (m, f), jnp.float32, -2.0, 2.0)
    vals = jax.random.uniform(kb, (m,), jnp.float32, -2.0, 2.0)
    feats = jax.random.uniform(kc, (f,), jnp.float32, -2.0, 2.0)
    got = spmm_update(c, vals, feats)
    want = ref.spmm_col_ref(c, vals, feats)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_zero_vals_is_identity():
    c = jnp.ones((4, 8))
    out = spmm_update(c, jnp.zeros((4,)), jnp.ones((8,)))
    np.testing.assert_array_equal(out, c)
