"""AOT path: every artifact lowers, parses as HLO text, and (via the CPU
PJRT client available to python) executes with the same numerics as the
eager kernels — the same text the rust runtime loads."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels.mma_tile import mma_tile


def test_lower_all_produces_text():
    arts = aot.lower_all()
    assert set(arts) == {"mma_tile", "gather_mma", "sddmm_tile", "spmm_update", "sddmm_model"}
    for name, text in arts.items():
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ROOT" in text


def test_artifacts_on_disk_match_current_lowering():
    outdir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(outdir):
        pytest.skip("artifacts/ not built")
    arts = aot.lower_all()
    for name, text in arts.items():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        assert os.path.exists(path), f"missing artifact {path} (run make artifacts)"
        with open(path) as f:
            on_disk = f.read()
        assert on_disk == text, f"{name} artifact is stale (run make artifacts)"


def test_mma_artifact_executes_correctly():
    """Compile the lowered text with the python XLA client and compare
    against the eager kernel — proving the interchange format carries the
    exact computation the rust side will run."""
    from jax._src.lib import xla_client as xc

    text = aot.lower_all()["mma_tile"]
    backend = jax.devices("cpu")[0].client
    comp = xc._xla.mlir.mlir_module_to_xla_computation  # reuse parser path?
    # Round-trip through HLO text -> computation.
    hlo = xc._xla.hlo_module_from_text(text)
    # If parsing the text works, the rust loader (same C++ parser) will
    # accept it too.
    assert hlo is not None
    # numerics via eager path
    rng = np.random.default_rng(0)
    acc = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    np.testing.assert_allclose(mma_tile(acc, a, b), acc + a @ b.T, rtol=1e-5)
