"""L2 model graphs vs dense references (shapes + numerics)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def random_pattern(rng, m, n, density):
    dense = (rng.random((m, n)) < density).astype(np.float32)
    rows_by_col = [np.nonzero(dense[:, c])[0].tolist() for c in range(n)]
    return dense, rows_by_col


def test_build_groups_structure():
    rows_by_col = [[0, 5, 9], list(range(20)), []]
    idx, mask, cols, vals = model.build_groups(rows_by_col)
    # col 0: 1 group; col 1: 2 groups (20 nnz); col 2: none
    assert idx.shape == (3, 16)
    assert cols.tolist() == [0, 1, 1]
    assert mask[0].sum() == 3
    assert mask[1].sum() == 16
    assert mask[2].sum() == 4
    # padding indices are 0 with mask 0
    assert idx[0, 3:].tolist() == [0] * 13


def test_build_groups_empty():
    idx, mask, cols, vals = model.build_groups([[], []])
    assert idx.shape == (0, 16)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), density=st.floats(0.02, 0.3))
def test_sddmm_matches_dense(seed, density):
    rng = np.random.default_rng(seed)
    m, n, f = 24, 20, 32
    dense_mask, rows_by_col = random_pattern(rng, m, n, density)
    a = jnp.asarray(rng.standard_normal((m, f)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    idx, mask, cols, _ = model.build_groups(rows_by_col)
    if idx.shape[0] == 0:
        return
    out = model.sddmm(a, b, idx, mask, cols)
    want_dense = model.sddmm_dense_ref(a, b, jnp.asarray(dense_mask))
    # compare group-by-group against the dense reference
    for g in range(idx.shape[0]):
        for i in range(16):
            if mask[g, i] == 0.0:
                assert float(out[g, i]) == 0.0
            else:
                r, c = int(idx[g, i]), int(cols[g])
                np.testing.assert_allclose(
                    float(out[g, i]), float(want_dense[r, c]), rtol=2e-4, atol=2e-4
                )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), density=st.floats(0.02, 0.3))
def test_spmm_matches_dense(seed, density):
    rng = np.random.default_rng(seed)
    m, k, f = 24, 20, 32
    dense_pat, rows_by_col = random_pattern(rng, m, k, density)
    svals = dense_pat * rng.standard_normal((m, k)).astype(np.float32)
    vals_by_col = [svals[rows_by_col[c], c].tolist() for c in range(k)]
    b = jnp.asarray(rng.standard_normal((k, f)), jnp.float32)
    idx, mask, cols, vals = model.build_groups(rows_by_col, vals_by_col)
    c0 = jnp.zeros((m, f), jnp.float32)
    if idx.shape[0] == 0:
        return
    got = model.spmm(c0, jnp.asarray(vals), jnp.asarray(idx), jnp.asarray(mask),
                     jnp.asarray(cols), b)
    want = model.spmm_dense_ref(jnp.asarray(svals), b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_spmm_accumulates_onto_initial_c():
    b = jnp.ones((2, 4), jnp.float32)
    idx, mask, cols, vals = model.build_groups([[1]], [[2.0]])
    c0 = jnp.full((3, 4), 5.0)
    got = model.spmm(c0, jnp.asarray(vals), jnp.asarray(idx), jnp.asarray(mask),
                     jnp.asarray(cols), b)
    want = c0.at[1].add(2.0)
    np.testing.assert_allclose(got, want)
