"""Kernel vs ref correctness — the CORE numeric signal of the stack.

Hypothesis sweeps tile shapes (the simulator issues mma at every
matrixM/K/N combination) and seeds; every Pallas kernel must match its
pure-jnp oracle to f32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gather_mma import gather_mma
from compile.kernels.mma_tile import mma_tile
from compile.kernels.sddmm_tile import sddmm_tile
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=16)
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def rand(key, *shape):
    return jax.random.uniform(key, shape, jnp.float32, -2.0, 2.0)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=SEEDS)
def test_mma_tile_matches_ref(m, k, n, seed):
    ka, kb, kc = jax.random.split(jax.random.PRNGKey(seed), 3)
    acc, a, b = rand(ka, m, n), rand(kb, m, k), rand(kc, n, k)
    got = mma_tile(acc, a, b)
    want = ref.mma_tile_ref(acc, a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, r=st.integers(min_value=1, max_value=64), seed=SEEDS)
def test_gather_mma_matches_ref(m, k, n, r, seed):
    ka, kb, kc, kd = jax.random.split(jax.random.PRNGKey(seed), 4)
    acc = rand(ka, m, n)
    a_buf = rand(kb, r, k)
    b = rand(kc, n, k)
    idx = jax.random.randint(kd, (m,), 0, r, jnp.int32)
    got = gather_mma(acc, a_buf, idx, b)
    want = ref.gather_mma_ref(acc, a_buf, idx, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=SEEDS, density=st.floats(0.0, 1.0))
def test_sddmm_tile_matches_ref(m, k, n, seed, density):
    ka, kb, kc = jax.random.split(jax.random.PRNGKey(seed), 3)
    a, b = rand(ka, m, k), rand(kb, n, k)
    mask = (jax.random.uniform(kc, (m, n)) < density).astype(jnp.float32)
    got = sddmm_tile(a, b, mask)
    want = ref.sddmm_tile_ref(a, b, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # unsampled positions are exactly zero
    np.testing.assert_array_equal(np.asarray(got)[np.asarray(mask) == 0.0], 0.0)


def test_mma_tile_zero_padding_is_exact():
    """Zero-padded rows/cols contribute nothing (the rust runtime pads
    sub-16 tiles to the fixed 16x16 artifact shape)."""
    key = jax.random.PRNGKey(0)
    ka, kb, kc = jax.random.split(key, 3)
    m, k, n = 5, 7, 3
    acc, a, b = rand(ka, m, n), rand(kb, m, k), rand(kc, n, k)
    accp = jnp.zeros((16, 16)).at[:m, :n].set(acc)
    ap = jnp.zeros((16, 16)).at[:m, :k].set(a)
    bp = jnp.zeros((16, 16)).at[:n, :k].set(b)
    got = mma_tile(accp, ap, bp)[:m, :n]
    want = ref.mma_tile_ref(acc, a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # padding region stays zero
    full = mma_tile(accp, ap, bp)
    np.testing.assert_array_equal(np.asarray(full)[m:, :], 0.0)


def test_gather_mma_duplicate_indices():
    """Gathering the same row twice is legal (blockified patterns can
    produce repeated base addresses)."""
    a_buf = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
    idx = jnp.array([3, 3, 0, 7], jnp.int32)
    b = jnp.eye(4, dtype=jnp.float32)
    acc = jnp.zeros((4, 4), jnp.float32)
    got = gather_mma(acc, a_buf, idx, b)
    np.testing.assert_allclose(got, a_buf[idx], rtol=1e-6)


def test_mma_accumulates_not_overwrites():
    acc = jnp.full((2, 2), 10.0)
    a = jnp.zeros((2, 3))
    b = jnp.zeros((2, 3))
    np.testing.assert_array_equal(mma_tile(acc, a, b), acc)
