"""Layer-2: the paper's workload compute graphs in JAX, calling the
Layer-1 Pallas kernels.

The MPU executes SpMM/SDDMM as sequences of densified tile operations;
this module is the same computation expressed as a JAX graph over the
kernels — the numerical ground truth the rust simulator is validated
against, and the source of the AOT artifacts the rust runtime executes.

Group encoding (mirrors the rust kernel compilers): each sparse column's
nonzeros are chunked into groups of <= 16; a group carries the gathered
row indices (padded), a validity mask, and its column id.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.gather_mma import gather_mma
from .kernels.mma_tile import mma_tile

GROUP = 16


def build_groups(rows_by_col, vals_by_col=None):
    """Host-side grouping: ``rows_by_col[c]`` is the sorted nonzero row
    list of column ``c``. Returns (idx [G,16] i32, mask [G,16] f32,
    cols [G] i32, vals [G,16] f32) numpy arrays (padded with row 0,
    mask 0). ``vals_by_col`` defaults to ones (SDDMM pattern use)."""
    idx, mask, cols, vals = [], [], [], []
    for c, rows in enumerate(rows_by_col):
        cvals = vals_by_col[c] if vals_by_col is not None else [1.0] * len(rows)
        for g in range(0, len(rows), GROUP):
            chunk = list(rows[g : g + GROUP])
            vchunk = list(cvals[g : g + GROUP])
            pad = GROUP - len(chunk)
            idx.append(chunk + [0] * pad)
            mask.append([1.0] * len(chunk) + [0.0] * pad)
            vals.append(vchunk + [0.0] * pad)
            cols.append(c)
    if not idx:
        z = np.zeros((0, GROUP), np.float32)
        return np.zeros((0, GROUP), np.int32), z, np.zeros((0,), np.int32), z
    return (
        np.asarray(idx, np.int32),
        np.asarray(mask, np.float32),
        np.asarray(cols, np.int32),
        np.asarray(vals, np.float32),
    )


def sddmm(a, b, idx, mask, cols):
    """SDDMM over grouped samples: ``out[g,i] = <A[idx[g,i]], B[cols[g]]>``
    masked by validity — each group is one densified GSA operation
    (gather 16 A rows, MMA against the column's B row).

    a: [M, F], b: [N, F], idx: [G, 16] i32, mask: [G, 16], cols: [G] i32.
    Returns [G, 16] sampled dot products (0 at padding).
    """

    def one_group(carry, g):
        gi, gm, gc = g
        acc = jnp.zeros((GROUP, 1), jnp.float32)
        bt = b[gc][None, :]  # [1, F] — the ms2 tile (matrixN = 1)
        out = gather_mma(acc, a, gi, bt)  # [16, 1]
        return carry, out[:, 0] * gm

    _, outs = jax.lax.scan(one_group, None, (idx, mask, cols))
    return outs


def spmm(c_init, vals, idx, mask, cols, b):
    """SpMM over grouped nonzeros: for each group (one sparse column's
    chunk), ``C[idx[g]] += vals[g] * B[cols[g]]`` — the densified
    rank-1 batch computed with the mma tile kernel (K = 1) and applied
    with a scatter-add, mirroring ``mgather -> mma -> mscatter``.

    c_init: [M, F], vals/mask: [G, 16], idx: [G, 16] i32, cols: [G] i32,
    b: [K, F]. Returns the accumulated C.
    """

    def one_group(c, g):
        gv, gi, gm, gc = g
        c_rows = c[gi]  # mgather: the C rows under update
        a = (gv * gm)[:, None]  # [16, 1] masked values (ms1, K = 1)
        bt = b[gc][:, None]  # [F, 1] features as ms2 rows (N = F, K = 1)
        updated = mma_tile(c_rows, a, bt)  # c_rows + vals (x) feats
        # mscatter as a scatter-add of the delta: padding lanes carry a
        # zero delta, so their duplicate row-0 indices are harmless.
        return c.at[gi].add(updated - c_rows), None

    c, _ = jax.lax.scan(one_group, c_init, (vals, idx, mask, cols))
    return c


def sddmm_dense_ref(a, b, mask_dense):
    """Dense reference for tests: ``(A @ B^T) * mask``."""
    return (a @ b.T) * mask_dense


def spmm_dense_ref(s_dense, b):
    """Dense reference for tests: ``S @ B``."""
    return s_dense @ b
