"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` rust crate) rejects; the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Artifacts (all lowered with return_tuple=True; rust unwraps to_tuple1):

  mma_tile.hlo.txt     (acc[16,16], a[16,16], b[16,16]) -> acc + a@b^T
  gather_mma.hlo.txt   (acc[16,16], a_buf[256,16], idx[16]i32, b[16,16])
  sddmm_tile.hlo.txt   (a[16,16], b[16,16], mask[16,16]) -> (a@b^T)*mask
  spmm_update.hlo.txt  (c[16,64], vals[16], feats[64]) -> c + vals(x)feats
  sddmm_model.hlo.txt  L2 grouped-SDDMM graph (8 groups, 64x64, F=32)

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.gather_mma import gather_mma
from .kernels.mma_tile import mma_tile
from .kernels.sddmm_tile import sddmm_tile
from .kernels.spmm_update import spmm_update
from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_all():
    """Return {artifact name: hlo text}."""
    arts = {}

    def fn_mma(acc, a, b):
        return (mma_tile(acc, a, b),)

    arts["mma_tile"] = to_hlo_text(
        jax.jit(fn_mma).lower(f32(16, 16), f32(16, 16), f32(16, 16))
    )

    def fn_gather(acc, a_buf, idx, b):
        return (gather_mma(acc, a_buf, idx, b),)

    arts["gather_mma"] = to_hlo_text(
        jax.jit(fn_gather).lower(f32(16, 16), f32(256, 16), i32(16), f32(16, 16))
    )

    def fn_sddmm_tile(a, b, mask):
        return (sddmm_tile(a, b, mask),)

    arts["sddmm_tile"] = to_hlo_text(
        jax.jit(fn_sddmm_tile).lower(f32(16, 16), f32(16, 16), f32(16, 16))
    )

    def fn_spmm_update(c_rows, vals, feats):
        return (spmm_update(c_rows, vals, feats),)

    arts["spmm_update"] = to_hlo_text(
        jax.jit(fn_spmm_update).lower(f32(16, 64), f32(16), f32(64))
    )

    def fn_sddmm_model(a, b, idx, mask, cols):
        return (model.sddmm(a, b, idx, mask, cols),)

    arts["sddmm_model"] = to_hlo_text(
        jax.jit(fn_sddmm_model).lower(
            f32(64, 32), f32(64, 32), i32(8, 16), f32(8, 16), i32(8)
        )
    )
    return arts


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--outdir", default="../artifacts")
    args = p.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    for name, text in lower_all().items():
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>8} chars -> {path}")


if __name__ == "__main__":
    main()
