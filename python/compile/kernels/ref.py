"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package is checked against its oracle by ``python/tests`` (hypothesis
sweeps shapes and seeds), and the rust simulator's functional mode is
checked against the same semantics through the AOT artifacts.
"""

import jax.numpy as jnp


def mma_tile_ref(acc, a, b):
    """Systolic tile semantics (DARE ``mma``): ``acc += a @ b.T``.

    acc: [M, N], a: [M, K], b: [N, K] (operand shapes matrixM x matrixK
    and matrixN x matrixK, paper section III-A).
    """
    return acc + a @ b.T


def gather_mma_ref(acc, a_buf, idx, b):
    """GSA densified operation: gather rows of ``a_buf`` then MMA.

    acc: [M, N], a_buf: [R, K] (the backing array the base-address
    vector points into), idx: [M] int32 row indices, b: [N, K].
    ``out = acc + a_buf[idx] @ b.T``
    """
    return acc + a_buf[idx] @ b.T


def sddmm_tile_ref(a, b, mask):
    """Sampled tile product: ``(a @ b.T) * mask``.

    a: [M, K], b: [N, K], mask: [M, N] (1.0 at sampled positions).
    """
    return (a @ b.T) * mask


def spmm_col_ref(c_rows, vals, feats):
    """SpMM densified column update (batched rank-1).

    c_rows: [M, F] gathered C rows, vals: [M] nonzero values of one
    sparse column, feats: [F] the B row of that column.
    ``out = c_rows + vals[:, None] * feats[None, :]``
    """
    return c_rows + vals[:, None] * feats[None, :]
