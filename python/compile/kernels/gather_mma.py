"""Layer-1 Pallas kernel: the GSA densified operation (``mgather`` +
``mma`` fused).

The paper's core compute insight is that multiple logically-related
sparse operations can be *densified* into one dense MMA once the ISA can
address operand rows non-contiguously. On the MPU that is
``mgather md, (ms1)`` followed by ``mma``; on the TPU-shaped stack the
same insight becomes this kernel: a per-row dynamic gather from the
A buffer (HBM->VMEM schedule expressed by the index operand) feeding a
single MXU tile contraction.

GPU->TPU re-think (DESIGN.md section Hardware-Adaptation): instead of a
threadblock staging scattered rows through shared memory, the kernel
receives the index vector as a scalar-prefetch-style operand and issues
``M`` dynamic row slices from the (VMEM-resident for this scale) A
buffer; the MMA maps to one MXU pass. ``interpret=True`` for CPU PJRT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 16


def _gather_mma_kernel(acc_ref, a_buf_ref, idx_ref, b_ref, o_ref):
    m = acc_ref.shape[0]
    k = a_buf_ref.shape[1]
    # Gather M rows by dynamic index — the mgather semantics. In
    # interpret mode each pl.load with a dynamic row index is a dynamic
    # slice; on real TPU hardware this lowers to per-row VMEM moves.
    rows = []
    for i in range(m):  # m is static (trace-time) — unrolled row moves
        r = idx_ref[i]
        row = pl.load(a_buf_ref, (pl.dslice(r, 1), pl.dslice(0, k)))
        rows.append(row)
    a = jnp.concatenate(rows, axis=0)
    prod = jax.lax.dot_general(
        a,
        b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = acc_ref[...] + prod


@functools.partial(jax.jit, static_argnames=())
def gather_mma(acc, a_buf, idx, b):
    """``acc[M,N] += a_buf[idx][M,K] @ b[N,K]^T``.

    acc: [M, N] f32; a_buf: [R, K] f32 backing buffer; idx: [M] int32;
    b: [N, K] f32.
    """
    m, n = acc.shape
    return pl.pallas_call(
        _gather_mma_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(acc, a_buf, idx, b)


def gather_mma_full(acc, a_buf, idx, b):
    """Fixed-shape entry (M=N=16, K=16, R=256) for AOT lowering."""
    assert acc.shape == (TILE, TILE) and idx.shape == (TILE,)
    return gather_mma(acc, a_buf, idx, b)
