"""Layer-1 Pallas kernel: sampled dense-dense tile product (SDDMM).

``out[M,N] = (a[M,K] @ b[N,K]^T) * mask[M,N]`` — the dense tile compute
of the paper's SDDMM benchmark. The mask carries the sparsity pattern of
the sampled block; multiplying after the MXU contraction matches how the
MPU discards unsampled lanes (only the gathered rows were real work, the
rest of the tile is masked).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 16


def _sddmm_kernel(a_ref, b_ref, mask_ref, o_ref):
    prod = jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = prod * mask_ref[...]


@functools.partial(jax.jit, static_argnames=())
def sddmm_tile(a, b, mask):
    """``(a @ b.T) * mask`` as a Pallas call."""
    m = a.shape[0]
    n = b.shape[0]
    return pl.pallas_call(
        _sddmm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b, mask)


def sddmm_tile_full(a, b, mask):
    """Fixed-shape (16,16,16) entry for AOT lowering."""
    assert a.shape == (TILE, TILE) and b.shape == (TILE, TILE)
    return sddmm_tile(a, b, mask)
