"""Layer-1 Pallas kernel: the SpMM densified column update.

One GSA SpMM step (`mgather C -> mma -> mscatter C`, see
rust/src/kernels/spmm.rs) updates m gathered C rows with a batched
rank-1 product: ``C_rows += vals (x) feats``. As an MXU operation this
is a K=1 contraction: ``a = vals[:, None]`` (ms1, matrixK = 4 bytes),
``b = feats[:, None]`` (ms2, features as rows).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 16


def _spmm_update_kernel(c_ref, vals_ref, feats_ref, o_ref):
    vals = vals_ref[...]  # [M]
    feats = feats_ref[...]  # [F]
    o_ref[...] = c_ref[...] + vals[:, None] * feats[None, :]


@functools.partial(jax.jit, static_argnames=())
def spmm_update(c_rows, vals, feats):
    """``c_rows[M,F] += vals[M] (x) feats[F]``."""
    m, f = c_rows.shape
    return pl.pallas_call(
        _spmm_update_kernel,
        out_shape=jax.ShapeDtypeStruct((m, f), jnp.float32),
        interpret=True,
    )(c_rows, vals, feats)


def spmm_update_full(c_rows, vals, feats):
    """Fixed-shape (16, 64) entry for AOT lowering."""
    assert c_rows.shape == (TILE, 64) and vals.shape == (TILE,) and feats.shape == (64,)
    return spmm_update(c_rows, vals, feats)
