"""Layer-1 Pallas kernel: the systolic MMA tile.

This is the compute hot-spot of the DARE MPU — one ``mma`` instruction
(``C[MxN] += A[MxK] @ B[NxK]^T``) expressed as a Pallas kernel. The rust
runtime executes the AOT-lowered artifact for every retired ``mma`` in
functional mode, so simulated numerics really are produced by this
kernel.

Hardware adaptation (DESIGN.md section Hardware-Adaptation): the paper's
16x16 systolic array with 32-bit PEs maps onto the MXU as a single
f32 tile contraction; both operands are VMEM-resident tiles (a full
16x16 f32 tile is 1 KiB — far under the ~16 MiB VMEM budget), and the
contraction is a single MXU pass per tile. ``interpret=True`` is
mandatory on CPU: real-TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The architectural tile edge (16 rows x 16 f32 per matrix register).
TILE = 16


def _mma_kernel(acc_ref, a_ref, b_ref, o_ref):
    """o = acc + a @ b.T over full VMEM-resident tiles."""
    a = a_ref[...]
    b = b_ref[...]
    # Contract the K dimension on the MXU; preferred_element_type pins the
    # accumulator to f32 (the paper's 32-bit PE datapath).
    prod = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = acc_ref[...] + prod


@functools.partial(jax.jit, static_argnames=())
def mma_tile(acc, a, b):
    """``acc[M,N] += a[M,K] @ b[N,K]^T`` as a Pallas call.

    All operands are padded-to-16 tiles (padding rows/cols are zero, which
    is exact for a matmul-accumulate).
    """
    m, n = acc.shape
    return pl.pallas_call(
        _mma_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(acc, a, b)


def mma_tile_full(acc, a, b):
    """Fixed-shape (16,16,16) entry point for AOT lowering."""
    assert acc.shape == (TILE, TILE) and a.shape == (TILE, TILE) and b.shape == (TILE, TILE)
    return mma_tile(acc, a, b)
