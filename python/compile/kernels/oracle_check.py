#!/usr/bin/env python3
"""Out-of-process differential checker behind ``dare oracle``.

Reads one JSON case from stdin (sparse CSC operand, the exact dense
operand bytes the rust compilers generated, and the simulator's raw
output region), recomputes the kernel with the reference functions in
``ref.py``, and prints a one-line JSON verdict::

    {"ok": true, "max_rel_err": 1.2e-7, "n": 2048}

``ref.py`` imports ``jax.numpy``; offline runners only have numpy, so a
module shim substitutes numpy for jax.numpy before the import — every
reference function here is pure array arithmetic, identical under both.

Exit status: 0 when the check *ran* (even if the verdict is ``ok:
false`` — the rust side owns pass/fail aggregation), nonzero only when
the checker itself is broken (bad input, import failure).
"""

import json
import os
import sys
import types

import numpy as np

if "jax" not in sys.modules:
    _jax = types.ModuleType("jax")
    _jax.numpy = np
    sys.modules["jax"] = _jax
    sys.modules["jax.numpy"] = np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import ref  # noqa: E402


def recompute(case):
    """The expected output region, in the simulator's layout."""
    f = int(case["f"])
    nrows, ncols = int(case["nrows"]), int(case["ncols"])
    col_ptr = np.asarray(case["col_ptr"], dtype=np.int64)
    row_idx = np.asarray(case["row_idx"], dtype=np.int64)
    vals = np.asarray(case["vals"], dtype=np.float32)
    b = np.asarray(case["b"], dtype=np.float32).reshape(ncols, f)

    if case["kernel"] == "spmm":
        # C[M,F] = S·B, accumulated column-by-column with the densified
        # rank-1 update reference. Row indices within one CSC column are
        # unique, so the fancy-indexed read-modify-write is exact.
        out = np.zeros((nrows, f), dtype=np.float32)
        for j in range(ncols):
            lo, hi = col_ptr[j], col_ptr[j + 1]
            if lo == hi:
                continue
            idx = row_idx[lo:hi]
            out[idx] = ref.spmm_col_ref(out[idx], vals[lo:hi], b[j])
        return out.reshape(-1)

    if case["kernel"] == "sddmm":
        # out[nnz] = (A·Bᵀ) sampled at the pattern, in CSC order. The
        # compiled kernel samples the *pattern* only (values unused), so
        # the mask is 1.0 at every stored position.
        a = np.asarray(case["a"], dtype=np.float32).reshape(nrows, f)
        mask = np.zeros((nrows, ncols), dtype=np.float32)
        for j in range(ncols):
            mask[row_idx[col_ptr[j]:col_ptr[j + 1]], j] = 1.0
        full = np.asarray(ref.sddmm_tile_ref(a, b, mask))
        parts = [full[row_idx[col_ptr[j]:col_ptr[j + 1]], j] for j in range(ncols)]
        if not parts:
            return np.zeros(0, dtype=np.float32)
        return np.concatenate(parts)

    raise ValueError("unknown kernel %r" % case.get("kernel"))


def main():
    case = json.load(sys.stdin)
    want = recompute(case).astype(np.float32)
    got = np.asarray(case["sim"], dtype=np.float32)
    tol = float(case.get("tol", 1e-3))
    if got.shape != want.shape:
        print(json.dumps({
            "ok": False,
            "detail": "shape mismatch: sim %s vs ref %s" % (got.shape, want.shape),
        }))
        return
    # Same relative tolerance rule as Workload::verify on the rust side.
    scale = np.maximum(1.0, np.abs(want))
    rel = np.abs(got - want) / scale
    worst = int(np.argmax(rel)) if rel.size else 0
    ok = bool(rel.size == 0 or rel[worst] <= tol)
    print(json.dumps({
        "ok": ok,
        "max_rel_err": float(rel[worst]) if rel.size else 0.0,
        "n": int(want.size),
        "detail": "" if ok else "worst at [%d]: got %r want %r" % (
            worst, float(got[worst]), float(want[worst])),
    }))


if __name__ == "__main__":
    main()
